//! Zero-dependency TCP serving layer: a multi-model [`Registry`] behind a
//! pipelined wire protocol.
//!
//! A [`Server`] binds a std `TcpListener`, accepts connections on a
//! dedicated accept thread, and runs one lightweight reader thread plus
//! one reply-writer thread per connection. Every connection decodes
//! length-prefixed [`wire`] frames, routes each to the named model's
//! [`Coordinator`](crate::coordinator::Coordinator) in the shared
//! [`Registry`], and forwards it with the *client's* request id and a
//! per-connection reply channel
//! ([`Coordinator::submit_with`](crate::coordinator::Coordinator::submit_with)).
//! Replies flow back through the writer thread as each model's executor
//! completes them — so one connection can keep up to
//! [`wire::MAX_INFLIGHT`] frames in flight, replies are matched by id, and
//! a fast model's replies overtake a slow model's. Each model keeps the
//! coordinator's leader/worker shape: the backend never leaves its
//! executor thread; the serving layer only adds transport and routing.
//!
//! Wire v1 clients are served unchanged (no hello frame → v1 decoding →
//! the default model), and the blocking [`Client`] still sees strictly
//! ordered replies because it keeps one request in flight.
//!
//! Error containment mirrors the wire contract: a request that frames
//! correctly but decodes badly gets an error *reply* echoing its id and
//! the connection lives on; only a torn frame header or an oversized
//! length closes the connection (after a best-effort error reply). A
//! stalled client trips the write timeout, after which its replies are
//! drained and discarded — a dead connection can never block a model's
//! executor. Server counters (`served`, `wire_errors`, `learns`) are
//! process-wide atomics reported through the Stats opcode together with
//! the target model's knowledge counters.

pub mod client;
pub mod registry;
pub mod wire;

pub use client::{Client, InferReply, ServerError};
pub use registry::{ModelSpec, Registry};
pub use wire::{ReqBody, WireRequest, WireResponse, WireStats};

use crate::coordinator::{Payload, ReplyKind, Response};
use crate::hdc::SearchMode;
use crate::Result;
use anyhow::Context;
use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Serving knobs.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// per-frame payload cap (default [`wire::MAX_FRAME`])
    pub max_frame: usize,
    /// honor client-supplied Snapshot *paths*. Off by default: the wire
    /// protocol is unauthenticated, and a remote path would be an
    /// arbitrary-file-write primitive. When off, clients may still send an
    /// empty path to checkpoint to the server's configured default.
    pub allow_snapshot_paths: bool,
    /// per-connection in-flight frame cap, clamped to
    /// `1..=`[`wire::MAX_INFLIGHT`] (further frames are simply not read
    /// until replies drain — TCP backpressure)
    pub max_inflight: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_frame: wire::MAX_FRAME,
            allow_snapshot_paths: false,
            max_inflight: wire::MAX_INFLIGHT,
        }
    }
}

/// Process-wide serving counters (lock-free; read by the Stats opcode).
#[derive(Debug, Default)]
pub struct ServerStats {
    /// frames served (all opcodes, error replies included)
    pub served: AtomicU64,
    /// frames that decoded badly (the error-reply count)
    pub wire_errors: AtomicU64,
    /// successful Learn replies across all models
    pub learns: AtomicU64,
}

/// A running TCP server. Dropping (or calling [`Server::stop`]) shuts the
/// accept loop down, joins every connection thread, and finally drops the
/// registry — each model's coordinator drains its queue and runs its
/// executor's shutdown snapshot flush.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    stats: Arc<ServerStats>,
}

impl Server {
    /// Bind `listen` (e.g. `127.0.0.1:0` for an ephemeral test port) and
    /// start serving the registry over it.
    pub fn start(listen: &str, registry: Registry, opts: ServeOptions) -> Result<Server> {
        let listener = TcpListener::bind(listen).with_context(|| format!("bind {listen}"))?;
        // non-blocking accept: shutdown must never depend on the wakeup
        // poke reaching the socket (it can't on e.g. a firewalled bind)
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(ServerStats::default());
        let registry = Arc::new(registry);
        let accept = {
            let (stop, stats) = (stop.clone(), stats.clone());
            std::thread::Builder::new()
                .name("clo-hdnn-accept".into())
                .spawn(move || accept_loop(listener, registry, stats, stop, opts))?
        };
        Ok(Server { addr, stop, accept: Some(accept), stats })
    }

    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Counter snapshot: (served, wire_errors, learns).
    pub fn counters(&self) -> (u64, u64, u64) {
        (
            self.stats.served.load(Ordering::Relaxed),
            self.stats.wire_errors.load(Ordering::Relaxed),
            self.stats.learns.load(Ordering::Relaxed),
        )
    }

    /// Graceful shutdown: stop accepting, join connections, drop the
    /// registry (each model flushes its shutdown snapshot if configured).
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // the accept loop polls the stop flag (non-blocking accept), so
        // this join is bounded even when no wakeup connection can land
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: TcpListener,
    registry: Arc<Registry>,
    stats: Arc<ServerStats>,
    stop: Arc<AtomicBool>,
    opts: ServeOptions,
) {
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // nothing pending: nap briefly, then re-check the stop flag
                std::thread::sleep(Duration::from_millis(25));
                continue;
            }
            Err(_) => {
                // transient accept error (e.g. ECONNABORTED): don't spin
                std::thread::sleep(Duration::from_millis(25));
                continue;
            }
        };
        // accepted sockets may inherit the listener's non-blocking mode on
        // some platforms; connections use blocking reads with a timeout
        if stream.set_nonblocking(false).is_err() {
            continue;
        }
        let (registry, stats, stop, opts) =
            (registry.clone(), stats.clone(), stop.clone(), opts.clone());
        match std::thread::Builder::new()
            .name("clo-hdnn-conn".into())
            .spawn(move || {
                let _ = handle_conn(stream, &registry, &stats, &stop, &opts);
            }) {
            Ok(h) => conns.push(h),
            Err(_) => continue,
        }
        conns.retain(|h| !h.is_finished());
    }
    for h in conns {
        let _ = h.join();
    }
    // `registry` (the last Arc once clients are gone) drops here: every
    // model's executor drains, flushes its shutdown snapshot, and exits
}

/// Shared write half of a connection. The reply-writer thread and the
/// reader (hello acks, pre-dispatch error replies) both write whole frames
/// under the lock, so frames never interleave.
type SharedWriter = Arc<Mutex<BufWriter<TcpStream>>>;

/// Write one reply frame directly (reader-side control path). Any failure
/// marks the connection dead — there is no way to retry a partial frame.
fn write_direct(writer: &SharedWriter, resp: &WireResponse, dead: &AtomicBool) {
    if dead.load(Ordering::Relaxed) {
        return;
    }
    let ok = match writer.lock() {
        Ok(mut w) => wire::write_frame(&mut *w, &resp.encode()).is_ok(),
        Err(_) => false,
    };
    if !ok {
        dead.store(true, Ordering::Relaxed);
    }
}

/// Translate an executor reply onto the wire using its [`ReplyKind`] tag —
/// the stateless mapping that lets replies complete out of order.
fn translate(resp: &Response, stats: &ServerStats) -> WireResponse {
    let id = resp.id;
    if let Some(msg) = &resp.error {
        return WireResponse::Error { id, msg: msg.clone() };
    }
    match resp.kind {
        ReplyKind::Classify => WireResponse::Infer {
            id,
            class: resp.class.unwrap_or(0) as u32,
            segments: resp.segments_used as u32,
            early: resp.early_exit,
        },
        ReplyKind::Learn => WireResponse::Learn { id, class: resp.class.unwrap_or(0) as u32 },
        ReplyKind::Snapshot | ReplyKind::Restore => WireResponse::Snapshot {
            id,
            path: resp.detail.clone().unwrap_or_default(),
        },
        ReplyKind::Stats => {
            let k = resp.stats.unwrap_or_default();
            WireResponse::Stats {
                id,
                stats: WireStats {
                    served: stats.served.load(Ordering::Relaxed),
                    wire_errors: stats.wire_errors.load(Ordering::Relaxed),
                    learns: k.learns,
                    trained_classes: k.trained_classes as u32,
                    snapshots: k.snapshots,
                },
            }
        }
    }
}

/// The reply-writer loop: drain executor replies off the connection's
/// channel, translate, write. When the connection dies (stalled client,
/// torn socket) it keeps draining and discarding so a model's executor can
/// never block on a dead connection's reply channel. Exits when every
/// sender (the reader plus all in-flight requests) is gone.
fn reply_loop(
    rx: mpsc::Receiver<Response>,
    writer: SharedWriter,
    inflight: Arc<AtomicUsize>,
    dead: Arc<AtomicBool>,
    stats: Arc<ServerStats>,
) {
    while let Ok(resp) = rx.recv() {
        let frame = translate(&resp, &stats);
        if matches!(frame, WireResponse::Learn { .. }) {
            stats.learns.fetch_add(1, Ordering::Relaxed);
        }
        write_direct(&writer, &frame, &dead);
        inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// One connection: a reader loop (this thread) decoding and dispatching
/// frames, plus a reply-writer thread streaming executor replies back.
fn handle_conn(
    stream: TcpStream,
    registry: &Arc<Registry>,
    stats: &Arc<ServerStats>,
    stop: &AtomicBool,
    opts: &ServeOptions,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // short read timeout so idle connections observe the stop flag; a
    // write timeout so a client that stops reading can't pin the writer
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    stream.set_write_timeout(Some(Duration::from_secs(10)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let writer: SharedWriter = Arc::new(Mutex::new(BufWriter::new(stream)));
    let cap = opts.max_inflight.clamp(1, wire::MAX_INFLIGHT);
    // sized to the in-flight cap: with the reader gating submissions on
    // `inflight < cap`, an executor's reply send can never block
    let (reply_tx, reply_rx) = mpsc::sync_channel::<Response>(cap);
    let inflight = Arc::new(AtomicUsize::new(0));
    let dead = Arc::new(AtomicBool::new(false));
    let writer_thread = {
        let (writer, inflight, dead, stats) =
            (writer.clone(), inflight.clone(), dead.clone(), stats.clone());
        std::thread::Builder::new()
            .name("clo-hdnn-reply".into())
            .spawn(move || reply_loop(reply_rx, writer, inflight, dead, stats))?
    };
    let result = conn_reader(
        &mut reader, &writer, registry, stats, stop, opts, &reply_tx, &inflight, &dead, cap,
    );
    // close the reader's sender: once the in-flight requests complete, the
    // writer drains their replies and exits
    drop(reply_tx);
    let _ = writer_thread.join();
    result
}

/// The per-connection reader loop: frame → decode (at the negotiated
/// version) → route to the target model → submit with the client's id.
#[allow(clippy::too_many_arguments)]
fn conn_reader(
    reader: &mut BufReader<TcpStream>,
    writer: &SharedWriter,
    registry: &Registry,
    stats: &ServerStats,
    stop: &AtomicBool,
    opts: &ServeOptions,
    reply_tx: &mpsc::SyncSender<Response>,
    inflight: &AtomicUsize,
    dead: &AtomicBool,
    cap: usize,
) -> Result<()> {
    let mut version = wire::WIRE_V1;
    loop {
        if stop.load(Ordering::Relaxed) || dead.load(Ordering::Relaxed) {
            return Ok(());
        }
        let payload = match wire::read_frame(reader, opts.max_frame) {
            Ok(wire::Frame::Payload(p)) => p,
            Ok(wire::Frame::Eof) => return Ok(()),
            Ok(wire::Frame::Idle) => continue,
            Err(e) => {
                // framing is broken (torn header/body or oversized length):
                // best-effort error reply, then close — there is no way to
                // resynchronize the stream
                stats.wire_errors.fetch_add(1, Ordering::Relaxed);
                let reply = WireResponse::Error { id: 0, msg: format!("{e:#}") };
                write_direct(writer, &reply, dead);
                return Err(e);
            }
        };
        stats.served.fetch_add(1, Ordering::Relaxed);
        let req = match WireRequest::decode(&payload, version) {
            Err(e) => {
                // framed but garbled: error reply echoing the request id,
                // keep serving — the length prefix kept the stream in
                // sync, and the other in-flight requests (and every other
                // model) are untouched
                stats.wire_errors.fetch_add(1, Ordering::Relaxed);
                let reply = WireResponse::Error {
                    id: wire::peek_id(&payload),
                    msg: format!("{e:#}"),
                };
                write_direct(writer, &reply, dead);
                continue;
            }
            Ok(req) => req,
        };
        // hello: negotiate the version and advertise the registry, without
        // ever crossing an executor
        if let ReqBody::Hello { version: proposed } = &req.body {
            version = (*proposed).clamp(wire::WIRE_V1, wire::WIRE_V2);
            let ack = WireResponse::Hello {
                id: req.id,
                version,
                default_model: registry.default_name().to_string(),
                models: registry.names().to_vec(),
            };
            write_direct(writer, &ack, dead);
            continue;
        }
        // route to the target model
        let coord = match registry.get(&req.model) {
            Ok(c) => c,
            Err(e) => {
                let reply = WireResponse::Error { id: req.id, msg: format!("{e:#}") };
                write_direct(writer, &reply, dead);
                continue;
            }
        };
        let id = req.id;
        let payload = match req.body {
            ReqBody::Infer { mode, features } => match mode {
                wire::MODE_L1 => Payload::FeaturesWithMode(features, SearchMode::L1Int8),
                wire::MODE_PACKED => {
                    Payload::FeaturesWithMode(features, SearchMode::HammingPacked)
                }
                _ => Payload::Features(features),
            },
            ReqBody::Learn { class, features } => Payload::Learn(features, class as usize),
            ReqBody::Snapshot { path } => {
                if !path.is_empty() && !opts.allow_snapshot_paths {
                    let reply = WireResponse::Error {
                        id,
                        msg: "client-supplied snapshot paths are disabled on this server; \
                              send an empty path to checkpoint to the configured default"
                            .into(),
                    };
                    write_direct(writer, &reply, dead);
                    continue;
                }
                Payload::Snapshot(if path.is_empty() { None } else { Some(PathBuf::from(path)) })
            }
            ReqBody::Stats => Payload::Stats,
            ReqBody::Hello { .. } => unreachable!("hello handled above"),
        };
        // pipelining backpressure: wait for an in-flight slot before
        // submitting (keeps the reply channel from ever filling). A short
        // sleep-poll, engaged only at cap saturation: up to ~200us of
        // added dispatch latency per frame on a saturated connection —
        // accepted over a Condvar handshake with the writer for now
        // (replace if saturated-pipeline latency ever matters).
        loop {
            if inflight.load(Ordering::Relaxed) < cap {
                break;
            }
            if stop.load(Ordering::Relaxed) || dead.load(Ordering::Relaxed) {
                return Ok(());
            }
            std::thread::sleep(Duration::from_micros(200));
        }
        inflight.fetch_add(1, Ordering::Relaxed);
        if coord.submit_with(id, payload, reply_tx.clone()).is_err() {
            inflight.fetch_sub(1, Ordering::Relaxed);
            let reply = WireResponse::Error { id, msg: "model executor is gone".into() };
            write_direct(writer, &reply, dead);
        }
    }
}
