//! Zero-dependency TCP serving layer — the first over-the-wire workload.
//!
//! A [`Server`] binds a std `TcpListener`, accepts connections on a
//! dedicated accept thread, and runs one lightweight thread per
//! connection. Every connection decodes length-prefixed
//! [`wire`] frames and forwards them as [`Payload`]s to the shared
//! [`Coordinator`] — so concurrent clients multiplex onto the executor's
//! existing MPSC queue and their bursts batch through the same greedy
//! batcher in-process callers use (contiguous Learn runs still encode in
//! one backend call). The coordinator keeps its leader/worker shape: the
//! backend never leaves the executor thread; the serving layer only adds
//! transport.
//!
//! Error containment mirrors the wire contract: a request that frames
//! correctly but decodes badly gets an error *reply* and the connection
//! lives on; only a torn frame header or an oversized length closes the
//! connection (after a best-effort error frame). Server counters
//! (`served`, `wire_errors`, `learns`) are process-wide atomics reported
//! through the Stats opcode together with the coordinator's knowledge
//! counters.

pub mod client;
pub mod wire;

pub use client::{Client, InferReply};
pub use wire::{WireRequest, WireResponse, WireStats};

use crate::coordinator::{Coordinator, Payload};
use crate::hdc::SearchMode;
use crate::Result;
use anyhow::Context;
use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Serving knobs.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// per-frame payload cap (default [`wire::MAX_FRAME`])
    pub max_frame: usize,
    /// honor client-supplied Snapshot *paths*. Off by default: the wire
    /// protocol is unauthenticated, and a remote path would be an
    /// arbitrary-file-write primitive. When off, clients may still send an
    /// empty path to checkpoint to the server's configured default.
    pub allow_snapshot_paths: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { max_frame: wire::MAX_FRAME, allow_snapshot_paths: false }
    }
}

/// Process-wide serving counters (lock-free; read by the Stats opcode).
#[derive(Debug, Default)]
pub struct ServerStats {
    pub served: AtomicU64,
    pub wire_errors: AtomicU64,
    pub learns: AtomicU64,
}

/// A running TCP server. Dropping (or calling [`Server::stop`]) shuts the
/// accept loop down, joins every connection thread, and finally drops the
/// coordinator — which drains its queue and runs the executor's shutdown
/// snapshot flush.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    stats: Arc<ServerStats>,
}

impl Server {
    /// Bind `listen` (e.g. `127.0.0.1:0` for an ephemeral test port) and
    /// start serving the coordinator over it.
    pub fn start(listen: &str, coord: Coordinator, opts: ServeOptions) -> Result<Server> {
        let listener = TcpListener::bind(listen).with_context(|| format!("bind {listen}"))?;
        // non-blocking accept: shutdown must never depend on the wakeup
        // poke reaching the socket (it can't on e.g. a firewalled bind)
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(ServerStats::default());
        let coord = Arc::new(coord);
        let accept = {
            let (stop, stats) = (stop.clone(), stats.clone());
            std::thread::Builder::new()
                .name("clo-hdnn-accept".into())
                .spawn(move || accept_loop(listener, coord, stats, stop, opts))?
        };
        Ok(Server { addr, stop, accept: Some(accept), stats })
    }

    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Counter snapshot: (served, wire_errors, learns).
    pub fn counters(&self) -> (u64, u64, u64) {
        (
            self.stats.served.load(Ordering::Relaxed),
            self.stats.wire_errors.load(Ordering::Relaxed),
            self.stats.learns.load(Ordering::Relaxed),
        )
    }

    /// Graceful shutdown: stop accepting, join connections, drop the
    /// coordinator (which flushes the shutdown snapshot if configured).
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // the accept loop polls the stop flag (non-blocking accept), so
        // this join is bounded even when no wakeup connection can land
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: TcpListener,
    coord: Arc<Coordinator>,
    stats: Arc<ServerStats>,
    stop: Arc<AtomicBool>,
    opts: ServeOptions,
) {
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // nothing pending: nap briefly, then re-check the stop flag
                std::thread::sleep(Duration::from_millis(25));
                continue;
            }
            Err(_) => {
                // transient accept error (e.g. ECONNABORTED): don't spin
                std::thread::sleep(Duration::from_millis(25));
                continue;
            }
        };
        // accepted sockets may inherit the listener's non-blocking mode on
        // some platforms; connections use blocking reads with a timeout
        if stream.set_nonblocking(false).is_err() {
            continue;
        }
        let (coord, stats, stop, opts) =
            (coord.clone(), stats.clone(), stop.clone(), opts.clone());
        match std::thread::Builder::new()
            .name("clo-hdnn-conn".into())
            .spawn(move || {
                let _ = handle_conn(stream, &coord, &stats, &stop, &opts);
            }) {
            Ok(h) => conns.push(h),
            Err(_) => continue,
        }
        conns.retain(|h| !h.is_finished());
    }
    for h in conns {
        let _ = h.join();
    }
    // `coord` (the last Arc once clients are gone) drops here: the
    // executor drains, flushes its shutdown snapshot, and exits
}

/// One connection: read frame -> decode -> coordinator -> reply, until the
/// client closes, the stream tears, or the server stops.
fn handle_conn(
    stream: TcpStream,
    coord: &Coordinator,
    stats: &ServerStats,
    stop: &AtomicBool,
    opts: &ServeOptions,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // short read timeout so idle connections observe the stop flag
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        let payload = match wire::read_frame(&mut reader, opts.max_frame) {
            Ok(wire::Frame::Payload(p)) => p,
            Ok(wire::Frame::Eof) => return Ok(()),
            Ok(wire::Frame::Idle) => continue,
            Err(e) => {
                // framing is broken (torn header/body or oversized length):
                // best-effort error reply, then close — there is no way to
                // resynchronize the stream
                stats.wire_errors.fetch_add(1, Ordering::Relaxed);
                let reply = WireResponse::Error { id: 0, msg: format!("{e:#}") };
                let _ = wire::write_frame(&mut writer, &reply.encode());
                return Err(e);
            }
        };
        stats.served.fetch_add(1, Ordering::Relaxed);
        let reply = match WireRequest::decode(&payload) {
            Err(e) => {
                // framed but garbled: reply with an error, keep serving —
                // the length prefix kept the stream in sync
                stats.wire_errors.fetch_add(1, Ordering::Relaxed);
                WireResponse::Error { id: wire::peek_id(&payload), msg: format!("{e:#}") }
            }
            Ok(req) => dispatch(req, coord, stats, opts),
        };
        wire::write_frame(&mut writer, &reply.encode())?;
    }
}

/// Map a decoded wire request onto the coordinator and its reply back onto
/// the wire.
fn dispatch(
    req: WireRequest,
    coord: &Coordinator,
    stats: &ServerStats,
    opts: &ServeOptions,
) -> WireResponse {
    match req {
        WireRequest::Infer { id, mode, features } => {
            let payload = match mode {
                wire::MODE_L1 => Payload::FeaturesWithMode(features, SearchMode::L1Int8),
                wire::MODE_PACKED => {
                    Payload::FeaturesWithMode(features, SearchMode::HammingPacked)
                }
                _ => Payload::Features(features),
            };
            match coord.call(payload) {
                Err(e) => WireResponse::Error { id, msg: format!("{e:#}") },
                Ok(r) => match r.error {
                    Some(msg) => WireResponse::Error { id, msg },
                    None => WireResponse::Infer {
                        id,
                        class: r.class.unwrap_or(0) as u32,
                        segments: r.segments_used as u32,
                        early: r.early_exit,
                    },
                },
            }
        }
        WireRequest::Learn { id, class, features } => {
            match coord.call(Payload::Learn(features, class as usize)) {
                Err(e) => WireResponse::Error { id, msg: format!("{e:#}") },
                Ok(r) => match r.error {
                    Some(msg) => WireResponse::Error { id, msg },
                    None => {
                        stats.learns.fetch_add(1, Ordering::Relaxed);
                        WireResponse::Learn { id, class }
                    }
                },
            }
        }
        WireRequest::Snapshot { id, path } => {
            if !path.is_empty() && !opts.allow_snapshot_paths {
                return WireResponse::Error {
                    id,
                    msg: "client-supplied snapshot paths are disabled on this server; \
                          send an empty path to checkpoint to the configured default"
                        .into(),
                };
            }
            let target = if path.is_empty() { None } else { Some(PathBuf::from(path)) };
            match coord.call(Payload::Snapshot(target)) {
                Err(e) => WireResponse::Error { id, msg: format!("{e:#}") },
                Ok(r) => match r.error {
                    Some(msg) => WireResponse::Error { id, msg },
                    None => WireResponse::Snapshot { id, path: r.detail.unwrap_or_default() },
                },
            }
        }
        WireRequest::Stats { id } => match coord.call(Payload::Stats) {
            Err(e) => WireResponse::Error { id, msg: format!("{e:#}") },
            Ok(r) => match r.error {
                Some(msg) => WireResponse::Error { id, msg },
                None => {
                    let k = r.stats.unwrap_or_default();
                    WireResponse::Stats {
                        id,
                        stats: WireStats {
                            served: stats.served.load(Ordering::Relaxed),
                            wire_errors: stats.wire_errors.load(Ordering::Relaxed),
                            learns: k.learns,
                            trained_classes: k.trained_classes as u32,
                            snapshots: k.snapshots,
                        },
                    }
                }
            },
        },
    }
}
