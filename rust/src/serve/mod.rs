//! Zero-dependency TCP serving layer: a multi-model [`Registry`] behind a
//! pipelined wire protocol, served by a single event-driven reactor.
//!
//! A [`Server`] binds a std `TcpListener` and runs one reactor thread
//! that owns every client socket: non-blocking accepts, incremental frame
//! reassembly ([`wire::FrameAssembler`]), per-connection write buffers
//! with partial-write continuation, and readiness-driven scheduling over a
//! `poll(2)` shim — so connection count is bounded by file descriptors and
//! buffer memory, not threads. Each decoded frame is routed to the named
//! model's [`Coordinator`](crate::coordinator::Coordinator) in the shared
//! [`Registry`] with the *client's* request id and a non-blocking reply
//! sink
//! ([`Coordinator::try_submit_sink`](crate::coordinator::Coordinator::try_submit_sink));
//! completed replies land back in the owning connection's write buffer as
//! each executor finishes them. One connection can keep up to
//! [`wire::MAX_INFLIGHT`] frames in flight, replies are matched by id, and
//! a fast model's replies overtake a slow model's. Each model keeps the
//! coordinator's leader/worker shape: the backend never leaves its
//! executor thread; the serving layer only adds transport and routing.
//!
//! Wire v1 clients are served unchanged (no hello frame → v1 decoding →
//! the default model), and the blocking [`Client`] still sees strictly
//! ordered replies because it keeps one request in flight.
//!
//! Error containment mirrors the wire contract: a request that frames
//! correctly but decodes badly gets an error *reply* echoing its id and
//! the connection lives on; only a torn frame (EOF mid-frame) or an
//! oversized length closes the connection (after a best-effort error
//! reply). Hostile or broken peers are bounded in every dimension: a
//! connection beyond [`ServeOptions::max_conns`] is shed at accept, a
//! silent one is closed at [`ServeOptions::idle_timeout`], and one that
//! stops reading its replies is shed once its write buffer stalls past
//! [`ServeOptions::write_stall_timeout`] or grows past
//! [`ServeOptions::max_wbuf`] — in every case without an executor ever
//! blocking. Server counters (`served`, `wire_errors`, `learns`, `sheds`)
//! are process-wide atomics reported through the Stats opcode together
//! with the target model's knowledge counters; per-connection counters are
//! reported by the reactor itself through the ConnStats opcode.

pub mod client;
mod reactor;
pub mod registry;
pub mod replica;
pub mod wire;

pub use client::{
    Client, Fleet, FleetOptions, FleetTargetReport, InferReply, RecvTimeout, ServerError,
    WalTailReply,
};
pub use registry::{ModelSpec, Registry};
pub use replica::{ModelSync, ModelSyncOptions, Replica, ReplicaOptions, ReplicaStatus};
pub use wire::{ReqBody, WireConnStats, WireRequest, WireResponse, WireStats};

use crate::coordinator::{ReplyKind, Response};
use crate::Result;
use anyhow::Context;
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Default [`ServeOptions::idle_timeout`] in seconds: how long a
/// connection may sit with no request bytes and nothing owed before the
/// server closes it.
pub const DEFAULT_IDLE_TIMEOUT_SECS: u64 = 60;
/// Default [`ServeOptions::write_stall_timeout`] in seconds: how long
/// queued reply bytes may sit unaccepted by the peer's socket before the
/// connection is shed.
pub const DEFAULT_WRITE_STALL_SECS: u64 = 10;
/// Default [`ServeOptions::max_conns`]: simultaneous connections accepted
/// before new peers are shed with an error frame.
pub const DEFAULT_MAX_CONNS: usize = 10_240;
/// Default [`ServeOptions::max_wbuf`] in bytes: per-connection queued
/// reply cap before a non-reading peer is shed.
pub const DEFAULT_MAX_WBUF: usize = 4 * 1024 * 1024;

/// Serving knobs.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// per-frame payload cap (default [`wire::MAX_FRAME`])
    pub max_frame: usize,
    /// honor client-supplied Snapshot *paths*. Off by default: the wire
    /// protocol is unauthenticated, and a remote path would be an
    /// arbitrary-file-write primitive. When off, clients may still send an
    /// empty path to checkpoint to the server's configured default.
    pub allow_snapshot_paths: bool,
    /// per-connection in-flight frame cap, clamped to
    /// `1..=`[`wire::MAX_INFLIGHT`] (further frames are simply not read
    /// until replies drain — TCP backpressure)
    pub max_inflight: usize,
    /// close a connection that has sent no request bytes for this long
    /// while nothing is owed to it (default
    /// [`DEFAULT_IDLE_TIMEOUT_SECS`])
    pub idle_timeout: Duration,
    /// shed a connection whose queued replies have made no progress into
    /// the socket for this long (default [`DEFAULT_WRITE_STALL_SECS`])
    pub write_stall_timeout: Duration,
    /// simultaneous-connection cap; peers beyond it are shed at accept
    /// with a best-effort error frame (default [`DEFAULT_MAX_CONNS`])
    pub max_conns: usize,
    /// per-connection queued-reply-bytes cap; a peer that stops reading is
    /// shed once its buffer exceeds this (default [`DEFAULT_MAX_WBUF`])
    pub max_wbuf: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_frame: wire::MAX_FRAME,
            allow_snapshot_paths: false,
            max_inflight: wire::MAX_INFLIGHT,
            idle_timeout: Duration::from_secs(DEFAULT_IDLE_TIMEOUT_SECS),
            write_stall_timeout: Duration::from_secs(DEFAULT_WRITE_STALL_SECS),
            max_conns: DEFAULT_MAX_CONNS,
            max_wbuf: DEFAULT_MAX_WBUF,
        }
    }
}

/// Process-wide serving counters (lock-free; read by the Stats opcode).
#[derive(Debug, Default)]
pub struct ServerStats {
    /// frames served (all opcodes, error replies included)
    pub served: AtomicU64,
    /// frames that decoded badly (the error-reply count)
    pub wire_errors: AtomicU64,
    /// successful Learn replies across all models
    pub learns: AtomicU64,
    /// connections shed: refused at the connection cap, stalled past the
    /// write deadline, or over the write-buffer cap
    pub sheds: AtomicU64,
}

/// A running TCP server. Dropping (or calling [`Server::stop`]) flips the
/// stop flag, wakes the reactor, joins it, and — inside the reactor
/// thread — drops the registry: each model's coordinator drains its queue
/// and runs its executor's shutdown snapshot flush before the join
/// returns.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    waker: reactor::Waker,
    reactor: Option<std::thread::JoinHandle<()>>,
    stats: Arc<ServerStats>,
}

impl Server {
    /// Bind `listen` (e.g. `127.0.0.1:0` for an ephemeral test port) and
    /// start serving the registry over it.
    pub fn start(listen: &str, registry: Registry, opts: ServeOptions) -> Result<Server> {
        let listener = TcpListener::bind(listen).with_context(|| format!("bind {listen}"))?;
        // the reactor multiplexes accepts with connection I/O; everything
        // it owns is non-blocking
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(ServerStats::default());
        let (waker, waker_rx) = reactor::waker();
        let r = reactor::Reactor::new(
            listener,
            Arc::new(registry),
            stats.clone(),
            stop.clone(),
            opts,
            waker.clone(),
            waker_rx,
        );
        let handle = std::thread::Builder::new()
            .name("clo-hdnn-reactor".into())
            .spawn(move || r.run())?;
        Ok(Server { addr, stop, waker, reactor: Some(handle), stats })
    }

    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Counter snapshot: (served, wire_errors, learns).
    pub fn counters(&self) -> (u64, u64, u64) {
        (
            self.stats.served.load(Ordering::Relaxed),
            self.stats.wire_errors.load(Ordering::Relaxed),
            self.stats.learns.load(Ordering::Relaxed),
        )
    }

    /// Connections shed so far (capacity refusals + stalled-writer sheds).
    pub fn sheds(&self) -> u64 {
        self.stats.sheds.load(Ordering::Relaxed)
    }

    /// Graceful shutdown: stop the reactor (closing every connection),
    /// then drop the registry (each model flushes its shutdown snapshot if
    /// configured). Snapshots are on disk when this returns.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.waker.wake();
        if let Some(h) = self.reactor.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Translate an executor reply onto the wire using its [`ReplyKind`] tag —
/// the stateless mapping that lets replies complete out of order.
pub(crate) fn translate(resp: &Response, stats: &ServerStats) -> WireResponse {
    let id = resp.id;
    if let Some(msg) = &resp.error {
        return WireResponse::Error { id, msg: msg.clone() };
    }
    match resp.kind {
        ReplyKind::Classify => WireResponse::Infer {
            id,
            class: resp.class.unwrap_or(0) as u32,
            segments: resp.segments_used as u32,
            early: resp.early_exit,
            wcfe: resp.used_wcfe,
            escalated: resp.escalated,
            energy_j: resp.energy_j,
        },
        ReplyKind::Learn => WireResponse::Learn { id, class: resp.class.unwrap_or(0) as u32 },
        ReplyKind::Snapshot | ReplyKind::Restore => WireResponse::Snapshot {
            id,
            path: resp.detail.clone().unwrap_or_default(),
        },
        ReplyKind::Stats => {
            let k = resp.stats.unwrap_or_default();
            WireResponse::Stats {
                id,
                stats: WireStats {
                    served: stats.served.load(Ordering::Relaxed),
                    wire_errors: stats.wire_errors.load(Ordering::Relaxed),
                    learns: k.learns,
                    trained_classes: k.trained_classes as u32,
                    snapshots: k.snapshots,
                    learn_seq: k.learn_seq,
                    bypass: k.bypass,
                    normal: k.normal,
                    escalations: k.escalations,
                    policy: k.policy,
                    policy_margin: k.policy_margin,
                    epoch: k.epoch,
                },
            }
        }
        ReplyKind::WalTail => WireResponse::WalTail {
            id,
            base_seq: resp.wal_base.unwrap_or(0),
            last_seq: resp.stats.map(|s| s.learn_seq).unwrap_or(0),
            epoch: resp.stats.map(|s| s.epoch).unwrap_or(0),
            records: resp.records.clone().unwrap_or_default(),
        },
        ReplyKind::Promote => {
            let k = resp.stats.unwrap_or_default();
            WireResponse::Promote { id, epoch: k.epoch, base_seq: k.learn_seq }
        }
        ReplyKind::SnapshotImage => {
            let image = resp.image.clone().unwrap_or_default();
            // the reply header adds id/kind/last_seq/img_len (21 bytes);
            // refuse anything the frame cap could not carry rather than
            // tearing the connection down at write time
            if image.len() + 64 > wire::MAX_FRAME {
                return WireResponse::Error {
                    id,
                    msg: format!(
                        "snapshot image is {} bytes — too large for the \
                         {}-byte frame cap",
                        image.len(),
                        wire::MAX_FRAME
                    ),
                };
            }
            WireResponse::SnapshotImage {
                id,
                last_seq: resp.stats.map(|s| s.learn_seq).unwrap_or(0),
                image,
            }
        }
    }
}
