//! The serving reactor: one event-loop thread owning every client socket.
//!
//! The thread-per-connection server (PR 4/5) capped connection scale at
//! thread count and let one slow peer pin a thread. This module replaces
//! it with a single non-blocking readiness loop — `poll(2)` through a
//! minimal `extern "C"` shim on unix, a bounded-nap optimistic sweep
//! elsewhere — so ten thousand connections cost ten thousand small
//! buffers, not ten thousand stacks.
//!
//! Ownership split (see DESIGN.md): the **reactor owns sockets** — accept,
//! incremental frame reassembly ([`FrameAssembler`]), decode, routing,
//! write buffering, timeouts — while **executors own backends**, exactly
//! as before. The seam is [`ReplySink`]: the reactor hands each request to
//! a model's [`Coordinator`] with a non-blocking
//! [`Coordinator::try_submit_sink`], and the executor completes it onto an
//! unbounded channel tagged with the owning connection's token, poking a
//! loopback [`Waker`] so the loop wakes promptly. An executor can
//! therefore never block on — or be blocked by — any connection.
//!
//! Per-connection flow control: at most `max_inflight` (≤
//! [`wire::MAX_INFLIGHT`]) requests may be parsed-but-unanswered. At the
//! cap the reactor simply stops *reading* that socket — kernel-buffer
//! backpressure, no bookkeeping, nothing dropped. Slow readers accumulate
//! reply bytes in the connection's write buffer until either the
//! write-stall timeout or the buffer cap sheds them; silent connections
//! are closed at the idle timeout; connections beyond `max_conns` are
//! refused at accept with a best-effort error frame.

use crate::coordinator::{Coordinator, Payload, ReplySink, Response, TrySubmit};
use crate::hdc::SearchMode;
use crate::serve::registry::Registry;
use crate::serve::wire::{self, FrameAssembler, ReqBody, WireConnStats, WireRequest, WireResponse};
use crate::serve::{translate, ServeOptions, ServerStats};
use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::io::AsRawFd;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// poll(2) shim

/// What a poll entry wants to be woken for.
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct Interest {
    /// wake when the fd is readable (or the peer closed)
    pub read: bool,
    /// wake when the fd accepts writes again
    pub write: bool,
}

/// What [`Poller::wait`] observed for one entry.
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct Ready {
    /// readable now (a read will not block; 0 bytes means EOF)
    pub read: bool,
    /// writable now
    pub write: bool,
    /// error/hangup condition (`POLLERR`/`POLLHUP`/`POLLNVAL`)
    pub err: bool,
}

#[cfg(unix)]
#[repr(C)]
#[derive(Clone, Copy)]
struct PollFd {
    fd: i32,
    events: i16,
    revents: i16,
}

#[cfg(unix)]
const POLLIN: i16 = 0x001;
#[cfg(unix)]
const POLLOUT: i16 = 0x004;
#[cfg(unix)]
const POLLERR: i16 = 0x008;
#[cfg(unix)]
const POLLHUP: i16 = 0x010;
#[cfg(unix)]
const POLLNVAL: i16 = 0x020;

/// `nfds_t`: `unsigned long` on Linux, `unsigned int` on the BSD family.
#[cfg(all(unix, target_os = "linux"))]
type Nfds = std::os::raw::c_ulong;
#[cfg(all(unix, not(target_os = "linux")))]
type Nfds = std::os::raw::c_uint;

#[cfg(unix)]
extern "C" {
    fn poll(fds: *mut PollFd, nfds: Nfds, timeout: i32) -> i32;
}

/// Level-triggered readiness, `poll(2)`-backed on unix. The non-unix
/// fallback naps briefly and reports every interested entry ready — the
/// sockets are non-blocking, so a wrong guess costs one `WouldBlock`, not
/// correctness.
#[derive(Default)]
pub(crate) struct Poller {
    #[cfg(unix)]
    fds: Vec<PollFd>,
    ready: Vec<Ready>,
}

impl Poller {
    /// Wait up to `timeout` for readiness on `entries` (an fd plus its
    /// [`Interest`]; negative fds are skipped, matching `poll(2)`).
    /// Returns one [`Ready`] per entry, in order.
    pub fn wait(&mut self, entries: &[(i32, Interest)], timeout: Duration) -> &[Ready] {
        self.ready.clear();
        self.ready.resize(entries.len(), Ready::default());
        #[cfg(unix)]
        {
            self.fds.clear();
            for &(fd, want) in entries {
                let mut events = 0i16;
                if want.read {
                    events |= POLLIN;
                }
                if want.write {
                    events |= POLLOUT;
                }
                // entries with no interest are parked on fd -1 so they
                // cannot report spurious hangups either
                let fd = if events != 0 { fd } else { -1 };
                self.fds.push(PollFd { fd, events, revents: 0 });
            }
            let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
            let rc = unsafe { poll(self.fds.as_mut_ptr(), self.fds.len() as Nfds, ms) };
            if rc > 0 {
                for (i, p) in self.fds.iter().enumerate() {
                    let r = &mut self.ready[i];
                    r.read = p.revents & POLLIN != 0;
                    r.write = p.revents & POLLOUT != 0;
                    r.err = p.revents & (POLLERR | POLLHUP | POLLNVAL) != 0;
                }
            }
            // rc == 0: timeout; rc < 0: transient (EINTR) — either way the
            // caller loops and recomputes, nothing is lost
        }
        #[cfg(not(unix))]
        {
            std::thread::sleep(timeout.min(Duration::from_millis(5)));
            for (&(_, want), r) in entries.iter().zip(self.ready.iter_mut()) {
                r.read = want.read;
                r.write = want.write;
            }
        }
        &self.ready
    }
}

#[cfg(unix)]
pub(crate) fn stream_fd(s: &TcpStream) -> i32 {
    s.as_raw_fd()
}
#[cfg(unix)]
fn listener_fd(l: &TcpListener) -> i32 {
    l.as_raw_fd()
}
#[cfg(not(unix))]
pub(crate) fn stream_fd(_s: &TcpStream) -> i32 {
    0
}
#[cfg(not(unix))]
fn listener_fd(_l: &TcpListener) -> i32 {
    0
}

// ---------------------------------------------------------------------------
// waker

/// Wakes the reactor out of `poll` from another thread (an executor
/// completing a request, or [`Server::stop`](crate::serve::Server::stop)).
/// Implemented as the write end of a loopback socket pair the reactor
/// polls; when the pair cannot be built the waker is a no-op and the
/// reactor compensates with a short poll timeout.
#[derive(Clone)]
pub(crate) struct Waker {
    tx: Option<Arc<TcpStream>>,
}

impl Waker {
    /// Poke the reactor. Never blocks: the socket is non-blocking, and a
    /// full buffer means a wakeup byte is already pending.
    pub fn wake(&self) {
        if let Some(s) = &self.tx {
            let _ = (&**s).write(&[1u8]);
        }
    }
}

/// Build the waker and the read end the reactor polls. `(noop, None)` when
/// the loopback pair cannot be built (e.g. no loopback interface).
pub(crate) fn waker() -> (Waker, Option<TcpStream>) {
    fn pair() -> Option<(TcpStream, TcpStream)> {
        let l = TcpListener::bind("127.0.0.1:0").ok()?;
        let addr = l.local_addr().ok()?;
        let tx = TcpStream::connect(addr).ok()?;
        let (rx, _) = l.accept().ok()?;
        tx.set_nonblocking(true).ok()?;
        rx.set_nonblocking(true).ok()?;
        tx.set_nodelay(true).ok();
        Some((tx, rx))
    }
    match pair() {
        Some((tx, rx)) => (Waker { tx: Some(Arc::new(tx)) }, Some(rx)),
        None => (Waker { tx: None }, None),
    }
}

// ---------------------------------------------------------------------------
// the reply seam

/// The per-connection [`ReplySink`]: tags each completed [`Response`] with
/// the owning connection's token, pushes it onto the reactor's unbounded
/// completion channel, and wakes the loop. `complete` never blocks, which
/// is the whole point — see the module docs.
struct ConnSink {
    token: u64,
    /// `mpsc::Sender` is only `Sync` on newer toolchains; the mutex makes
    /// the sink unconditionally shareable at the cost of one uncontended
    /// lock per completion
    tx: Mutex<mpsc::Sender<(u64, Response)>>,
    waker: Waker,
}

impl ReplySink for ConnSink {
    fn complete(&self, resp: Response) {
        if let Ok(tx) = self.tx.lock() {
            let _ = tx.send((self.token, resp));
        }
        self.waker.wake();
    }
}

// ---------------------------------------------------------------------------
// per-connection state

/// One connection's reactor-side state: the socket, the reassembly buffer,
/// the write buffer with partial-write continuation, and the dispatch
/// window accounting.
struct Conn {
    stream: TcpStream,
    asm: FrameAssembler,
    /// negotiated wire version (v1 until a hello frame says otherwise)
    version: u32,
    /// queued reply bytes; `wpos..` is not yet accepted by the socket
    wbuf: Vec<u8>,
    wpos: usize,
    /// requests currently inside an executor
    inflight: usize,
    /// decoded requests waiting for an executor queue slot
    pending: VecDeque<(u64, Arc<Coordinator>, Payload)>,
    sink: Arc<ConnSink>,
    opened: Instant,
    last_read: Instant,
    /// last instant the write buffer was empty or draining (the stall
    /// clock measures from here)
    last_write_ok: Instant,
    read_eof: bool,
    /// tearing down: stop reading, flush what's queued, then close
    closing: bool,
    frames: u64,
    replies: u64,
    errors: u64,
    peak_window: u32,
}

impl Conn {
    fn new(stream: TcpStream, sink: Arc<ConnSink>, max_frame: usize, now: Instant) -> Conn {
        Conn {
            stream,
            asm: FrameAssembler::new(max_frame),
            version: wire::WIRE_V1,
            wbuf: Vec::new(),
            wpos: 0,
            inflight: 0,
            pending: VecDeque::new(),
            sink,
            opened: now,
            last_read: now,
            last_write_ok: now,
            read_eof: false,
            closing: false,
            frames: 0,
            replies: 0,
            errors: 0,
            peak_window: 0,
        }
    }

    /// Unanswered requests (in an executor or waiting for one) — what the
    /// ≤ `max_inflight` pipeline window bounds.
    fn window(&self) -> usize {
        self.inflight + self.pending.len()
    }

    /// Reply bytes queued but not yet accepted by the socket.
    fn queued(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    /// Append one reply frame to the write buffer (flushed by the loop).
    fn queue_resp(&mut self, resp: &WireResponse) {
        if matches!(resp, WireResponse::Error { .. }) {
            self.errors += 1;
        }
        self.replies += 1;
        let payload = resp.encode();
        self.wbuf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        self.wbuf.extend_from_slice(&payload);
    }

    /// The counters an [`ReqBody::ConnStats`] request reports.
    fn wire_stats(&self, token: u64, now: Instant) -> WireConnStats {
        WireConnStats {
            conn_id: token,
            age_ms: now.saturating_duration_since(self.opened).as_millis() as u64,
            frames: self.frames,
            replies: self.replies,
            errors: self.errors,
            inflight: self.inflight as u32,
            pending: self.pending.len() as u32,
            peak_window: self.peak_window,
            queued_write_bytes: self.queued() as u64,
        }
    }
}

// ---------------------------------------------------------------------------
// the reactor

/// The event loop: owns the listener, every connection, and the completion
/// channel executors answer on. Built by
/// [`Server::start`](crate::serve::Server::start), runs on one dedicated
/// thread until the stop flag flips, then drops every connection and
/// finally the registry (each model's executor drains and flushes its
/// shutdown snapshot before `run` returns).
pub(crate) struct Reactor {
    listener: TcpListener,
    registry: Arc<Registry>,
    stats: Arc<ServerStats>,
    stop: Arc<AtomicBool>,
    opts: ServeOptions,
    waker: Waker,
    waker_rx: Option<TcpStream>,
    done_tx: mpsc::Sender<(u64, Response)>,
    done_rx: mpsc::Receiver<(u64, Response)>,
    conns: HashMap<u64, Conn>,
    next_token: u64,
}

impl Reactor {
    pub fn new(
        listener: TcpListener,
        registry: Arc<Registry>,
        stats: Arc<ServerStats>,
        stop: Arc<AtomicBool>,
        opts: ServeOptions,
        waker: Waker,
        waker_rx: Option<TcpStream>,
    ) -> Reactor {
        let (done_tx, done_rx) = mpsc::channel();
        Reactor {
            listener,
            registry,
            stats,
            stop,
            opts,
            waker,
            waker_rx,
            done_tx,
            done_rx,
            conns: HashMap::new(),
            next_token: 1,
        }
    }

    pub fn run(mut self) {
        let registry = self.registry.clone();
        let stats = self.stats.clone();
        let opts = self.opts.clone();
        let cap = opts.max_inflight.clamp(1, wire::MAX_INFLIGHT);
        let mut poller = Poller::default();
        let mut entries: Vec<(i32, Interest)> = Vec::new();
        let mut order: Vec<u64> = Vec::new();
        loop {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let now = Instant::now();
            entries.clear();
            order.clear();
            entries.push((listener_fd(&self.listener), Interest { read: true, write: false }));
            let wfd = self.waker_rx.as_ref().map(stream_fd).unwrap_or(-1);
            entries.push((wfd, Interest { read: true, write: false }));
            // poll timeout: the nearest connection deadline, capped so the
            // stop flag is observed promptly (tightly when no waker exists)
            let mut timeout = if self.waker_rx.is_some() {
                Duration::from_millis(250)
            } else {
                Duration::from_millis(5)
            };
            for (&token, c) in &self.conns {
                let read = !c.read_eof && !c.closing && c.window() < cap;
                let write = c.queued() > 0;
                entries.push((stream_fd(&c.stream), Interest { read, write }));
                order.push(token);
                if c.queued() > 0 {
                    if let Some(dl) = c.last_write_ok.checked_add(opts.write_stall_timeout) {
                        timeout = timeout.min(dl.saturating_duration_since(now));
                    }
                }
                if c.window() == 0 && c.queued() == 0 && !c.read_eof && !c.closing {
                    if let Some(dl) = c.last_read.checked_add(opts.idle_timeout) {
                        timeout = timeout.min(dl.saturating_duration_since(now));
                    }
                }
            }
            let ready: Vec<Ready> = poller.wait(&entries, timeout).to_vec();
            let now = Instant::now();
            if ready[1].read {
                self.drain_waker();
            }
            // executor completions → owning connection's write buffer
            while let Ok((token, resp)) = self.done_rx.try_recv() {
                if let Some(conn) = self.conns.get_mut(&token) {
                    let frame = translate(&resp, &stats);
                    if matches!(frame, WireResponse::Learn { .. }) {
                        stats.learns.fetch_add(1, Ordering::Relaxed);
                    }
                    conn.inflight = conn.inflight.saturating_sub(1);
                    conn.queue_resp(&frame);
                }
                // completions for a token that died are simply dropped
            }
            let mut dead: Vec<u64> = Vec::new();
            for (i, &token) in order.iter().enumerate() {
                let conn = self.conns.get_mut(&token).expect("token tracked");
                if !process_conn(conn, token, ready[i + 2], now, &registry, &stats, &opts, cap) {
                    dead.push(token);
                }
            }
            for t in dead {
                self.conns.remove(&t);
            }
            if ready[0].read {
                self.accept_ready(now);
            }
        }
        // teardown: connections drop here (their sinks die with them; late
        // executor completions land on a closed channel and are ignored),
        // then the registry Arc drops — every executor drains its queue
        // and flushes its shutdown snapshot before run() returns, so
        // Server::stop's join really means "snapshots are on disk"
    }

    fn drain_waker(&mut self) {
        if let Some(rx) = self.waker_rx.as_mut() {
            let mut b = [0u8; 256];
            loop {
                match rx.read(&mut b) {
                    Ok(0) => break,
                    Ok(_) => continue,
                    Err(e) if would_block(&e) => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => break,
                }
            }
        }
    }

    /// Accept everything pending. Beyond `max_conns` a peer gets a
    /// best-effort error frame and an immediate close (graceful shed).
    fn accept_ready(&mut self, now: Instant) {
        loop {
            let stream = match self.listener.accept() {
                Ok((s, _)) => s,
                Err(e) if would_block(&e) => break,
                // transient (e.g. ECONNABORTED): retry on the next sweep
                Err(_) => break,
            };
            if self.conns.len() >= self.opts.max_conns {
                self.stats.sheds.fetch_add(1, Ordering::Relaxed);
                let _ = stream.set_nonblocking(true);
                let resp = WireResponse::Error {
                    id: 0,
                    msg: format!(
                        "server at connection capacity ({}); retry later",
                        self.opts.max_conns
                    ),
                };
                let payload = resp.encode();
                let mut buf = Vec::with_capacity(4 + payload.len());
                buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
                buf.extend_from_slice(&payload);
                let _ = (&stream).write(&buf);
                continue; // dropped → closed
            }
            if stream.set_nonblocking(true).is_err() {
                continue;
            }
            stream.set_nodelay(true).ok();
            let token = self.next_token;
            self.next_token += 1;
            let sink = Arc::new(ConnSink {
                token,
                tx: Mutex::new(self.done_tx.clone()),
                waker: self.waker.clone(),
            });
            self.conns.insert(token, Conn::new(stream, sink, self.opts.max_frame, now));
        }
    }
}

fn would_block(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// One connection's turn: read what the socket has, reassemble and handle
/// complete frames, dispatch toward executors, flush the write buffer, and
/// enforce the shed/idle deadlines. Returns `false` when the connection is
/// finished (cleanly or not) and must be removed.
#[allow(clippy::too_many_arguments)]
fn process_conn(
    conn: &mut Conn,
    token: u64,
    ready: Ready,
    now: Instant,
    registry: &Registry,
    stats: &ServerStats,
    opts: &ServeOptions,
    cap: usize,
) -> bool {
    // hangup with nothing readable: the peer is gone and nothing more can
    // be learned from the socket (readable hangups drain the data first)
    if ready.err && !ready.read {
        return false;
    }
    if ready.read && !conn.read_eof && !conn.closing {
        // bounded per-sweep read so one firehose connection cannot starve
        // the rest of the loop; level-triggered polling picks the rest up
        // on the next sweep
        let mut scratch = [0u8; 16 * 1024];
        let mut budget = 256 * 1024usize;
        loop {
            if budget == 0 {
                break;
            }
            let want = scratch.len().min(budget);
            match conn.stream.read(&mut scratch[..want]) {
                Ok(0) => {
                    conn.read_eof = true;
                    if conn.asm.mid_frame() {
                        // EOF inside a frame: unrecoverable framing error
                        stats.wire_errors.fetch_add(1, Ordering::Relaxed);
                        conn.queue_resp(&WireResponse::Error {
                            id: 0,
                            msg: "connection closed mid-frame".into(),
                        });
                        conn.closing = true;
                    }
                    break;
                }
                Ok(n) => {
                    conn.asm.extend(&scratch[..n]);
                    conn.last_read = now;
                    budget -= n;
                }
                Err(e) if would_block(&e) => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
    }
    // reassemble + handle, stopping at the pipeline window (unparsed bytes
    // wait in the assembler; unread bytes wait in the kernel — that IS the
    // backpressure)
    while !conn.closing && conn.window() < cap {
        match conn.asm.next_payload() {
            Ok(Some(payload)) => handle_frame(conn, token, &payload, registry, stats, opts, now),
            Ok(None) => break,
            Err(e) => {
                // oversized length: no resynchronization is possible
                stats.wire_errors.fetch_add(1, Ordering::Relaxed);
                conn.queue_resp(&WireResponse::Error { id: 0, msg: format!("{e:#}") });
                conn.closing = true;
            }
        }
    }
    dispatch(conn);
    if !flush(conn, now) {
        return false;
    }
    let queued = conn.queued();
    if queued > opts.max_wbuf {
        // the peer is not reading and the buffer cap is blown; an error
        // frame could not be delivered either — close outright
        stats.sheds.fetch_add(1, Ordering::Relaxed);
        return false;
    }
    if queued > 0 && now.saturating_duration_since(conn.last_write_ok) > opts.write_stall_timeout {
        stats.sheds.fetch_add(1, Ordering::Relaxed);
        return false;
    }
    if conn.window() == 0 && queued == 0 {
        if conn.read_eof || conn.closing {
            // everything owed has been delivered
            return false;
        }
        if now.saturating_duration_since(conn.last_read) > opts.idle_timeout {
            // best-effort goodbye; close regardless of writability
            conn.queue_resp(&WireResponse::Error {
                id: 0,
                msg: format!("idle timeout ({:?} without a request)", opts.idle_timeout),
            });
            let _ = flush(conn, now);
            return false;
        }
    }
    true
}

/// Handle one reassembled request payload: decode at the negotiated
/// version, answer hello/conn-stats in the reactor, route everything else
/// to the target model's pending queue.
#[allow(clippy::too_many_arguments)]
fn handle_frame(
    conn: &mut Conn,
    token: u64,
    payload: &[u8],
    registry: &Registry,
    stats: &ServerStats,
    opts: &ServeOptions,
    now: Instant,
) {
    stats.served.fetch_add(1, Ordering::Relaxed);
    conn.frames += 1;
    let req = match WireRequest::decode(payload, conn.version) {
        Err(e) => {
            // framed but garbled: error reply echoing the id, connection
            // lives — framing kept the stream in sync
            stats.wire_errors.fetch_add(1, Ordering::Relaxed);
            conn.queue_resp(&WireResponse::Error {
                id: wire::peek_id(payload),
                msg: format!("{e:#}"),
            });
            return;
        }
        Ok(req) => req,
    };
    match &req.body {
        // hello: negotiate and advertise, without crossing an executor
        ReqBody::Hello { version: proposed } => {
            conn.version = (*proposed).clamp(wire::WIRE_V1, wire::WIRE_V2);
            let ack = WireResponse::Hello {
                id: req.id,
                version: conn.version,
                default_model: registry.default_name().to_string(),
                models: registry.names(),
            };
            conn.queue_resp(&ack);
            return;
        }
        // per-connection stats: reactor-answered, so it works even when
        // every executor queue is saturated
        ReqBody::ConnStats => {
            let stats_now = conn.wire_stats(token, now);
            conn.queue_resp(&WireResponse::ConnStats { id: req.id, stats: stats_now });
            return;
        }
        // registry mutation: reactor-answered — the registry (not any one
        // executor) owns the model set. Booting/draining an executor
        // blocks the loop for the admin call's duration, which is the
        // point: the mutation is visible to every later frame.
        ReqBody::ModelAdd { name, source } => {
            let resp = match registry.add(name, source) {
                Ok(models) => {
                    WireResponse::ModelAdmin { id: req.id, op: wire::OP_MODEL_ADD, models }
                }
                Err(e) => WireResponse::Error { id: req.id, msg: format!("{e:#}") },
            };
            conn.queue_resp(&resp);
            return;
        }
        ReqBody::ModelRemove { name } => {
            let resp = match registry.remove(name) {
                Ok(models) => {
                    WireResponse::ModelAdmin { id: req.id, op: wire::OP_MODEL_REMOVE, models }
                }
                Err(e) => WireResponse::Error { id: req.id, msg: format!("{e:#}") },
            };
            conn.queue_resp(&resp);
            return;
        }
        _ => {}
    }
    let coord = match registry.get(&req.model) {
        Ok(c) => c,
        Err(e) => {
            conn.queue_resp(&WireResponse::Error { id: req.id, msg: format!("{e:#}") });
            return;
        }
    };
    let id = req.id;
    let exec_payload = match req.body {
        ReqBody::Infer { mode, features } => match mode {
            wire::MODE_L1 => Payload::FeaturesWithMode(features, SearchMode::L1Int8),
            wire::MODE_PACKED => Payload::FeaturesWithMode(features, SearchMode::HammingPacked),
            _ => Payload::Features(features),
        },
        ReqBody::Learn { class, features } => Payload::Learn(features, class as usize),
        ReqBody::InferImage { mode, pixels } => match mode {
            wire::MODE_L1 => Payload::ImageWithMode(pixels, SearchMode::L1Int8),
            wire::MODE_PACKED => Payload::ImageWithMode(pixels, SearchMode::HammingPacked),
            _ => Payload::Image(pixels),
        },
        ReqBody::LearnImage { class, pixels } => Payload::LearnImage(pixels, class as usize),
        ReqBody::Snapshot { path } => {
            if !path.is_empty() && !opts.allow_snapshot_paths {
                conn.queue_resp(&WireResponse::Error {
                    id,
                    msg: "client-supplied snapshot paths are disabled on this server; \
                          send an empty path to checkpoint to the configured default"
                        .into(),
                });
                return;
            }
            Payload::Snapshot(if path.is_empty() { None } else { Some(PathBuf::from(path)) })
        }
        ReqBody::Stats => Payload::Stats,
        ReqBody::WalTail { after } => Payload::WalTail { after },
        ReqBody::SnapshotFetch => Payload::SnapshotFetch,
        // the wire carries no source epoch: a server promoted over the
        // wire fences everything below its own lineage
        ReqBody::Promote => Payload::Promote { min_epoch: 0 },
        ReqBody::ConnStats
        | ReqBody::Hello { .. }
        | ReqBody::ModelAdd { .. }
        | ReqBody::ModelRemove { .. } => unreachable!("handled above"),
    };
    conn.pending.push_back((id, coord, exec_payload));
    conn.peak_window = conn.peak_window.max(conn.window() as u32);
}

/// Move pending requests into executors until a queue reports full (the
/// retry happens on the next sweep — a completion always wakes one).
fn dispatch(conn: &mut Conn) {
    while let Some((id, coord, payload)) = conn.pending.pop_front() {
        match coord.try_submit_sink(id, payload, conn.sink.clone()) {
            Ok(()) => conn.inflight += 1,
            Err(TrySubmit::Full(payload)) => {
                conn.pending.push_front((id, coord, payload));
                break;
            }
            Err(TrySubmit::Gone(_)) => {
                conn.queue_resp(&WireResponse::Error { id, msg: "model executor is gone".into() });
            }
        }
    }
}

/// Push buffered reply bytes until the socket pushes back. Partial writes
/// continue exactly where they stopped (`wpos`); consumed prefixes are
/// compacted lazily. Returns `false` on a dead socket.
fn flush(conn: &mut Conn, now: Instant) -> bool {
    while conn.wpos < conn.wbuf.len() {
        match conn.stream.write(&conn.wbuf[conn.wpos..]) {
            Ok(0) => return false,
            Ok(n) => {
                conn.wpos += n;
                conn.last_write_ok = now;
            }
            Err(e) if would_block(&e) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    if conn.wpos == conn.wbuf.len() {
        conn.wbuf.clear();
        conn.wpos = 0;
        conn.last_write_ok = now;
    } else if conn.wpos > 64 * 1024 {
        conn.wbuf.drain(..conn.wpos);
        conn.wpos = 0;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg(unix)]
    fn poller_reports_read_and_write_readiness() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let a = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        let (b, _) = l.accept().unwrap();
        a.set_nonblocking(true).unwrap();
        let mut p = Poller::default();
        // a fresh socket: writable (empty send buffer), not readable
        let e = [(stream_fd(&a), Interest { read: true, write: true })];
        let r = p.wait(&e, Duration::from_millis(200)).to_vec();
        assert!(r[0].write, "{r:?}");
        assert!(!r[0].read, "{r:?}");
        // after the peer writes, readable
        (&b).write_all(b"x").unwrap();
        let e = [(stream_fd(&a), Interest { read: true, write: false })];
        let mut saw_read = false;
        for _ in 0..50 {
            if p.wait(&e, Duration::from_millis(100))[0].read {
                saw_read = true;
                break;
            }
        }
        assert!(saw_read);
        // after the peer closes, err-or-read (data then hangup)
        drop(b);
        let mut saw_close = false;
        for _ in 0..50 {
            let r = p.wait(&e, Duration::from_millis(100)).to_vec();
            if r[0].read || r[0].err {
                saw_close = true;
                break;
            }
        }
        assert!(saw_close);
    }

    #[test]
    #[cfg(unix)]
    fn negative_fds_are_ignored() {
        let mut p = Poller::default();
        let e = [(-1, Interest { read: true, write: false })];
        let r = p.wait(&e, Duration::from_millis(1)).to_vec();
        assert!(!r[0].read && !r[0].write && !r[0].err);
    }

    #[test]
    fn waker_wakes_the_poller() {
        let (w, rx) = waker();
        let mut rx = match rx {
            Some(rx) => rx,
            None => return, // no loopback: the no-op waker is the contract
        };
        let mut p = Poller::default();
        w.wake();
        let e = [(stream_fd(&rx), Interest { read: true, write: false })];
        let mut woke = false;
        for _ in 0..50 {
            if p.wait(&e, Duration::from_millis(100))[0].read {
                woke = true;
                break;
            }
        }
        assert!(woke);
        // drain works and the channel goes quiet again
        let mut b = [0u8; 8];
        assert!(rx.read(&mut b).unwrap() >= 1);
        // clones wake too
        w.clone().wake();
        let mut woke = false;
        for _ in 0..50 {
            if p.wait(&e, Duration::from_millis(100))[0].read {
                woke = true;
                break;
            }
        }
        assert!(woke);
    }
}
