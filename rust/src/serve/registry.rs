//! Multi-model registry: N named `(Coordinator, knowledge)` entries behind
//! one server.
//!
//! Clo-HDnn's dual-mode story is that one chip hosts both easy datasets
//! (HDC-only bypass mode) and hard ones (WCFE + HDC); the registry is the
//! software shape of that — independently schedulable engines, FSL-HDnn
//! style. Each model owns its own executor thread (the backend never
//! leaves it), its own knowledge checkpoint cadence, and its own stats;
//! the serving reactor routes wire-v2 frames to entries by name, so one
//! slow model never blocks another's replies on a pipelined connection.
//! The ownership split is strict: the reactor owns every socket, each
//! registry entry's executor owns its backend, and the two meet only at
//! the coordinator's non-blocking submit/reply seam.
//!
//! The model set is mutable at runtime (the `OP_MODEL_ADD` /
//! `OP_MODEL_REMOVE` admin opcodes): [`Registry::add`] clones a hosted
//! model's executor configuration under a new name and boots it, and
//! [`Registry::remove`] tears a model down — the drop drains its executor
//! queue and runs the per-model shutdown snapshot flush, so knowledge is
//! on disk before the acknowledgement. Lookups hand out cloned
//! `Arc<Coordinator>`s, so a model removed mid-request finishes that
//! request before its executor shuts down.
//!
//! Dropping the registry drops every coordinator, which drains each
//! executor queue and runs the per-model shutdown snapshot flush.

use crate::coordinator::{Coordinator, CoordinatorOptions};
use crate::Result;
use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

/// One model to register: its registry name plus the full executor
/// configuration (backend, search mode, thread budget, knowledge wiring).
#[derive(Debug)]
pub struct ModelSpec {
    /// registry name — what wire-v2 frames address
    pub name: String,
    /// the model's executor configuration
    pub opts: CoordinatorOptions,
}

impl ModelSpec {
    /// Build a spec, stamping `name` into the options' model identity so
    /// the model's knowledge checkpoints carry it (and restores verify it).
    pub fn new(name: impl Into<String>, mut opts: CoordinatorOptions) -> ModelSpec {
        let name = name.into();
        opts.model = name.clone();
        ModelSpec { name, opts }
    }
}

/// One hosted model: its running coordinator plus the configuration it was
/// started from (the clone template for [`Registry::add`]; `None` when the
/// coordinator was started outside the registry via [`Registry::single`]).
struct Entry {
    coord: Arc<Coordinator>,
    template: Option<CoordinatorOptions>,
}

/// The mutable model set (everything a runtime add/remove touches moves
/// together under one lock).
struct Inner {
    models: BTreeMap<String, Entry>,
    /// registration order (the wire hello advertises it)
    order: Vec<String>,
}

/// Named coordinators behind one server. The first registered model is the
/// default — what v1 connections and empty-model v2 frames hit. The set is
/// runtime-mutable ([`Registry::add`]/[`Registry::remove`]) behind a
/// read-write lock; the default model is fixed for the server's lifetime
/// and can never be removed.
pub struct Registry {
    inner: RwLock<Inner>,
    default_model: String,
}

impl Registry {
    /// Start every model's coordinator (one executor thread each). The
    /// first spec becomes the default model. Fails on an empty spec list,
    /// an empty or duplicate name, or any executor failing to boot.
    pub fn start(specs: Vec<ModelSpec>) -> Result<Registry> {
        if specs.is_empty() {
            bail!("registry needs at least one model");
        }
        let default_model = specs[0].name.clone();
        let mut models = BTreeMap::new();
        let mut order = Vec::with_capacity(specs.len());
        for spec in specs {
            if spec.name.is_empty() {
                bail!("registry model names must be non-empty");
            }
            if models.contains_key(&spec.name) {
                bail!("duplicate registry model '{}'", spec.name);
            }
            let coord = Coordinator::start(spec.opts.clone())
                .with_context(|| format!("starting model '{}'", spec.name))?;
            order.push(spec.name.clone());
            models.insert(
                spec.name,
                Entry { coord: Arc::new(coord), template: Some(spec.opts) },
            );
        }
        Ok(Registry { inner: RwLock::new(Inner { models, order }), default_model })
    }

    /// Wrap an already-running coordinator as a one-model registry (the
    /// single-model serving path). The entry keeps no configuration
    /// template, so it cannot serve as an [`Registry::add`] source.
    pub fn single(name: impl Into<String>, coord: Coordinator) -> Registry {
        let name = name.into();
        let mut models = BTreeMap::new();
        models.insert(name.clone(), Entry { coord: Arc::new(coord), template: None });
        Registry {
            inner: RwLock::new(Inner { models, order: vec![name.clone()] }),
            default_model: name,
        }
    }

    /// Resolve a wire model name (`""` = the default model) to its live
    /// coordinator. The handle is a cloned `Arc`, so it stays valid even
    /// if the model is removed while the request is in flight.
    pub fn get(&self, model: &str) -> Result<Arc<Coordinator>> {
        let name = if model.is_empty() { self.default_model.as_str() } else { model };
        let inner = self.inner.read().expect("registry lock poisoned");
        inner.models.get(name).map(|e| e.coord.clone()).ok_or_else(|| {
            anyhow::anyhow!(
                "no model '{name}' on this server (have: {})",
                inner.order.join(", ")
            )
        })
    }

    /// Boot a new model named `name` at runtime, cloning the executor
    /// configuration of the hosted model `source` (`""` = the default
    /// model). Knowledge starts empty: the snapshot/WAL/restore paths of
    /// the source are re-derived per model (suffixed with the new name) so
    /// two models never share a file, and no warm restore is inherited.
    /// Returns the post-mutation model list.
    pub fn add(&self, name: &str, source: &str) -> Result<Vec<String>> {
        if name.is_empty() {
            bail!("registry model names must be non-empty");
        }
        let src_name = if source.is_empty() { self.default_model.as_str() } else { source };
        let mut opts = {
            let inner = self.inner.read().expect("registry lock poisoned");
            if inner.models.contains_key(name) {
                bail!("model '{name}' already exists on this server");
            }
            let src = inner
                .models
                .get(src_name)
                .ok_or_else(|| anyhow::anyhow!("no source model '{src_name}' to clone"))?;
            src.template.clone().ok_or_else(|| {
                anyhow::anyhow!(
                    "source model '{src_name}' keeps no configuration template \
                     (it was started outside the registry)"
                )
            })?
        };
        opts.model = name.to_string();
        opts.snapshot_path = opts.snapshot_path.map(|p| suffix_path(&p, name));
        opts.wal_path = opts.wal_path.map(|p| suffix_path(&p, name));
        // a clone starts with empty knowledge — inheriting the source's
        // warm restore would serve model A's checkpoint as model B's
        opts.restore_path = None;
        let coord = Coordinator::start(opts.clone())
            .with_context(|| format!("starting model '{name}'"))?;
        let mut inner = self.inner.write().expect("registry lock poisoned");
        // re-check: another add may have raced in while the executor booted
        if inner.models.contains_key(name) {
            bail!("model '{name}' already exists on this server");
        }
        inner.order.push(name.to_string());
        inner.models.insert(
            name.to_string(),
            Entry { coord: Arc::new(coord), template: Some(opts) },
        );
        Ok(inner.order.clone())
    }

    /// Tear down the named model at runtime. The default model (and `""`,
    /// which aliases it) is refused — a server always keeps the model its
    /// v1 clients are wired to. The removed coordinator is dropped outside
    /// the registry lock: its executor drains queued requests and runs the
    /// shutdown snapshot flush, so knowledge is durable when this returns
    /// (in-flight `Arc` holders extend the executor's life briefly but see
    /// only a drained, flushed model). Returns the post-mutation model
    /// list.
    pub fn remove(&self, name: &str) -> Result<Vec<String>> {
        if name.is_empty() || name == self.default_model {
            bail!("the default model '{}' cannot be removed", self.default_model);
        }
        let (entry, names) = {
            let mut inner = self.inner.write().expect("registry lock poisoned");
            let entry = inner
                .models
                .remove(name)
                .ok_or_else(|| anyhow::anyhow!("no model '{name}' on this server"))?;
            inner.order.retain(|n| n != name);
            (entry, inner.order.clone())
        };
        drop(entry);
        Ok(names)
    }

    /// The default model's name (what v1 clients are served by).
    pub fn default_name(&self) -> &str {
        &self.default_model
    }

    /// Every model name, in registration order.
    pub fn names(&self) -> Vec<String> {
        self.inner.read().expect("registry lock poisoned").order.clone()
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.inner.read().expect("registry lock poisoned").models.len()
    }

    /// Whether the registry is empty (never true for a started registry).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Derive a per-model sibling of a template path: `k.clok` cloned for
/// model `shadow` becomes `k.shadow.clok` (extension preserved so tooling
/// keyed on `.clok`/`.clog` keeps matching).
fn suffix_path(p: &std::path::Path, name: &str) -> std::path::PathBuf {
    match p.extension().and_then(|e| e.to_str()) {
        Some(ext) => p.with_extension(format!("{name}.{ext}")),
        None => p.with_extension(name),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HdConfig;
    use crate::coordinator::Payload;

    fn cfg(name: &str, classes: usize) -> HdConfig {
        HdConfig::synthetic(name, 8, 8, 32, 32, 8, classes)
    }

    #[test]
    fn starts_routes_and_defaults() {
        let reg = Registry::start(vec![
            ModelSpec::new("alpha", CoordinatorOptions::software(cfg("a", 4))),
            ModelSpec::new("beta", CoordinatorOptions::software(cfg("b", 6))),
        ])
        .unwrap();
        assert_eq!(reg.len(), 2);
        assert!(!reg.is_empty());
        assert_eq!(reg.default_name(), "alpha");
        assert_eq!(reg.names(), ["alpha".to_string(), "beta".to_string()]);
        // "" routes to the default; names route to their entries; stats
        // prove each entry is a live executor
        for name in ["", "alpha", "beta"] {
            let r = reg.get(name).unwrap().call(Payload::Stats).unwrap();
            assert!(r.error.is_none(), "{name}: {:?}", r.error);
        }
        let e = reg.get("gamma").unwrap_err().to_string();
        assert!(e.contains("gamma") && e.contains("alpha"), "{e}");
    }

    #[test]
    fn rejects_bad_spec_lists() {
        assert!(Registry::start(vec![]).is_err());
        assert!(Registry::start(vec![ModelSpec::new(
            "",
            CoordinatorOptions::software(cfg("a", 4))
        )])
        .is_err());
        assert!(Registry::start(vec![
            ModelSpec::new("dup", CoordinatorOptions::software(cfg("a", 4))),
            ModelSpec::new("dup", CoordinatorOptions::software(cfg("b", 4))),
        ])
        .is_err());
    }

    #[test]
    fn spec_stamps_model_identity_into_options() {
        let spec = ModelSpec::new("gamma", CoordinatorOptions::software(cfg("g", 4)));
        assert_eq!(spec.opts.model, "gamma");
    }

    #[test]
    fn single_wraps_a_running_coordinator() {
        let coord = Coordinator::start(CoordinatorOptions::software(cfg("solo", 4))).unwrap();
        let reg = Registry::single("solo", coord);
        assert_eq!(reg.default_name(), "solo");
        assert_eq!(reg.len(), 1);
        assert!(reg.get("").unwrap().call(Payload::Stats).unwrap().error.is_none());
        // no template ⇒ cannot be cloned as an add source
        let e = reg.add("clone", "").unwrap_err().to_string();
        assert!(e.contains("template"), "{e}");
    }

    #[test]
    fn add_clones_geometry_and_remove_tears_down() {
        let reg = Registry::start(vec![ModelSpec::new(
            "alpha",
            CoordinatorOptions::software(cfg("a", 4)),
        )])
        .unwrap();
        // add from the default template; the new model serves immediately
        assert_eq!(reg.add("shadow", "").unwrap(), ["alpha", "shadow"]);
        let r = reg.get("shadow").unwrap().call(Payload::Stats).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.stats.unwrap().learns, 0, "clones start with empty knowledge");
        // duplicates and bad sources refused
        assert!(reg.add("shadow", "").is_err());
        assert!(reg.add("x", "missing").is_err());
        assert!(reg.add("", "").is_err());
        // remove tears the clone down; the default is protected
        assert_eq!(reg.remove("shadow").unwrap(), ["alpha"]);
        assert!(reg.get("shadow").is_err());
        assert!(reg.remove("shadow").is_err(), "double remove");
        assert!(reg.remove("alpha").is_err(), "default model is protected");
        assert!(reg.remove("").is_err());
        assert_eq!(reg.names(), ["alpha".to_string()]);
    }

    #[test]
    fn add_derives_distinct_knowledge_paths() {
        let dir = std::env::temp_dir().join("clo_hdnn_registry_paths");
        std::fs::create_dir_all(&dir).unwrap();
        let mut opts = CoordinatorOptions::software(cfg("a", 4));
        opts.snapshot_path = Some(dir.join("k.clok"));
        opts.wal_path = Some(dir.join("k.clog"));
        let reg = Registry::start(vec![ModelSpec::new("alpha", opts)]).unwrap();
        reg.add("shadow", "alpha").unwrap();
        // a learn against the clone must land in the clone's own WAL
        let coord = reg.get("shadow").unwrap();
        let r = coord.call(Payload::Learn(vec![1.0; 8], 0)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        drop(coord);
        reg.remove("shadow").unwrap();
        assert!(dir.join("k.shadow.clog").exists(), "per-model WAL path");
        assert!(dir.join("k.shadow.clok").exists(), "shutdown flush wrote the clone's snapshot");
        drop(reg);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn suffix_path_keeps_extensions() {
        use std::path::Path;
        assert_eq!(suffix_path(Path::new("a/k.clok"), "m"), Path::new("a/k.m.clok"));
        assert_eq!(suffix_path(Path::new("k.clog"), "b2"), Path::new("k.b2.clog"));
        assert_eq!(suffix_path(Path::new("bare"), "m"), Path::new("bare.m"));
    }
}
