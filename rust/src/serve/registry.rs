//! Multi-model registry: N named `(Coordinator, knowledge)` entries behind
//! one server.
//!
//! Clo-HDnn's dual-mode story is that one chip hosts both easy datasets
//! (HDC-only bypass mode) and hard ones (WCFE + HDC); the registry is the
//! software shape of that — independently schedulable engines, FSL-HDnn
//! style. Each model owns its own executor thread (the backend never
//! leaves it), its own knowledge checkpoint cadence, and its own stats;
//! the serving reactor routes wire-v2 frames to entries by name, so one
//! slow model never blocks another's replies on a pipelined connection.
//! The ownership split is strict: the reactor owns every socket, each
//! registry entry's executor owns its backend, and the two meet only at
//! the coordinator's non-blocking submit/reply seam.
//!
//! Dropping the registry drops every coordinator, which drains each
//! executor queue and runs the per-model shutdown snapshot flush.

use crate::coordinator::{Coordinator, CoordinatorOptions};
use crate::Result;
use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::sync::Arc;

/// One model to register: its registry name plus the full executor
/// configuration (backend, search mode, thread budget, knowledge wiring).
#[derive(Debug)]
pub struct ModelSpec {
    /// registry name — what wire-v2 frames address
    pub name: String,
    /// the model's executor configuration
    pub opts: CoordinatorOptions,
}

impl ModelSpec {
    /// Build a spec, stamping `name` into the options' model identity so
    /// the model's knowledge checkpoints carry it (and restores verify it).
    pub fn new(name: impl Into<String>, mut opts: CoordinatorOptions) -> ModelSpec {
        let name = name.into();
        opts.model = name.clone();
        ModelSpec { name, opts }
    }
}

/// Named coordinators behind one server. The first registered model is the
/// default — what v1 connections and empty-model v2 frames hit.
pub struct Registry {
    models: BTreeMap<String, Arc<Coordinator>>,
    /// registration order (the wire hello advertises it)
    order: Vec<String>,
    default_model: String,
}

impl Registry {
    /// Start every model's coordinator (one executor thread each). The
    /// first spec becomes the default model. Fails on an empty spec list,
    /// an empty or duplicate name, or any executor failing to boot.
    pub fn start(specs: Vec<ModelSpec>) -> Result<Registry> {
        if specs.is_empty() {
            bail!("registry needs at least one model");
        }
        let default_model = specs[0].name.clone();
        let mut models = BTreeMap::new();
        let mut order = Vec::with_capacity(specs.len());
        for spec in specs {
            if spec.name.is_empty() {
                bail!("registry model names must be non-empty");
            }
            if models.contains_key(&spec.name) {
                bail!("duplicate registry model '{}'", spec.name);
            }
            let coord = Coordinator::start(spec.opts)
                .with_context(|| format!("starting model '{}'", spec.name))?;
            order.push(spec.name.clone());
            models.insert(spec.name, Arc::new(coord));
        }
        Ok(Registry { models, order, default_model })
    }

    /// Wrap an already-running coordinator as a one-model registry (the
    /// single-model serving path).
    pub fn single(name: impl Into<String>, coord: Coordinator) -> Registry {
        let name = name.into();
        let mut models = BTreeMap::new();
        models.insert(name.clone(), Arc::new(coord));
        Registry { models, order: vec![name.clone()], default_model: name }
    }

    /// Resolve a wire model name (`""` = the default model).
    pub fn get(&self, model: &str) -> Result<&Arc<Coordinator>> {
        let name = if model.is_empty() { self.default_model.as_str() } else { model };
        self.models.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "no model '{name}' on this server (have: {})",
                self.order.join(", ")
            )
        })
    }

    /// The default model's name (what v1 clients are served by).
    pub fn default_name(&self) -> &str {
        &self.default_model
    }

    /// Every model name, in registration order.
    pub fn names(&self) -> &[String] {
        &self.order
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.models.len()
    }

    /// Whether the registry is empty (never true for a started registry).
    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HdConfig;
    use crate::coordinator::Payload;

    fn cfg(name: &str, classes: usize) -> HdConfig {
        HdConfig::synthetic(name, 8, 8, 32, 32, 8, classes)
    }

    #[test]
    fn starts_routes_and_defaults() {
        let reg = Registry::start(vec![
            ModelSpec::new("alpha", CoordinatorOptions::software(cfg("a", 4))),
            ModelSpec::new("beta", CoordinatorOptions::software(cfg("b", 6))),
        ])
        .unwrap();
        assert_eq!(reg.len(), 2);
        assert!(!reg.is_empty());
        assert_eq!(reg.default_name(), "alpha");
        assert_eq!(reg.names(), ["alpha".to_string(), "beta".to_string()]);
        // "" routes to the default; names route to their entries; stats
        // prove each entry is a live executor
        for name in ["", "alpha", "beta"] {
            let r = reg.get(name).unwrap().call(Payload::Stats).unwrap();
            assert!(r.error.is_none(), "{name}: {:?}", r.error);
        }
        let e = reg.get("gamma").unwrap_err().to_string();
        assert!(e.contains("gamma") && e.contains("alpha"), "{e}");
    }

    #[test]
    fn rejects_bad_spec_lists() {
        assert!(Registry::start(vec![]).is_err());
        assert!(Registry::start(vec![ModelSpec::new(
            "",
            CoordinatorOptions::software(cfg("a", 4))
        )])
        .is_err());
        assert!(Registry::start(vec![
            ModelSpec::new("dup", CoordinatorOptions::software(cfg("a", 4))),
            ModelSpec::new("dup", CoordinatorOptions::software(cfg("b", 4))),
        ])
        .is_err());
    }

    #[test]
    fn spec_stamps_model_identity_into_options() {
        let spec = ModelSpec::new("gamma", CoordinatorOptions::software(cfg("g", 4)));
        assert_eq!(spec.opts.model, "gamma");
    }

    #[test]
    fn single_wraps_a_running_coordinator() {
        let coord = Coordinator::start(CoordinatorOptions::software(cfg("solo", 4))).unwrap();
        let reg = Registry::single("solo", coord);
        assert_eq!(reg.default_name(), "solo");
        assert_eq!(reg.len(), 1);
        assert!(reg.get("").unwrap().call(Payload::Stats).unwrap().error.is_none());
    }
}
