//! Dynamic batcher: accumulates queued requests up to the lowered batch
//! size or a deadline, whichever first (the standard serving trade-off —
//! the b8 executables amortize dispatch overhead across the batch).

use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Generic deadline batcher over any item type.
#[derive(Debug)]
pub struct Batcher<T> {
    pub policy: BatchPolicy,
    pending: Vec<T>,
    oldest: Option<Instant>,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Batcher<T> {
        Batcher { policy, pending: Vec::new(), oldest: None }
    }

    pub fn push(&mut self, item: T) {
        if self.pending.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.pending.push(item);
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Should the current batch be dispatched now?
    pub fn ready(&self) -> bool {
        if self.pending.is_empty() {
            return false;
        }
        self.pending.len() >= self.policy.max_batch
            || self.oldest.map(|t| t.elapsed() >= self.policy.max_wait).unwrap_or(false)
    }

    /// Time until the deadline fires (for blocking waits); None if empty.
    pub fn time_to_deadline(&self) -> Option<Duration> {
        self.oldest
            .map(|t| self.policy.max_wait.saturating_sub(t.elapsed()))
    }

    /// Take up to max_batch items.
    pub fn take(&mut self) -> Vec<T> {
        let n = self.pending.len().min(self.policy.max_batch);
        let batch: Vec<T> = self.pending.drain(..n).collect();
        self.oldest = if self.pending.is_empty() {
            None
        } else {
            Some(Instant::now())
        };
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_to_max_batch() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(60) });
        b.push(1);
        b.push(2);
        assert!(!b.ready());
        b.push(3);
        assert!(b.ready());
        assert_eq!(b.take(), vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn deadline_fires() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(1) });
        b.push(1);
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.ready());
        assert_eq!(b.take(), vec![1]);
    }

    #[test]
    fn take_caps_at_max_batch() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(60) });
        for i in 0..5 {
            b.push(i);
        }
        assert_eq!(b.take(), vec![0, 1]);
        assert_eq!(b.len(), 3);
        assert!(b.ready()); // still >= max_batch
    }

    #[test]
    fn empty_never_ready() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy::default());
        assert!(!b.ready());
        assert!(b.time_to_deadline().is_none());
    }
}
