//! Dynamic batcher: accumulates queued requests up to the lowered batch
//! size or a deadline, whichever first (the standard serving trade-off —
//! the b8 executables amortize dispatch overhead across the batch).
//!
//! Flush timing is **event-driven**, not polled: [`Batcher::next_batch`]
//! blocks on the request channel with `recv` / `recv_timeout` (a condvar
//! wait inside std's mpsc), waking exactly when an item arrives or the
//! oldest item's deadline fires. The earlier executor shape — sleep a fixed
//! few milliseconds and re-check `ready()` — quantized flush latency to the
//! sleep period; with the blocking wait a deadline of `max_wait` flushes at
//! `max_wait`, not at the next poll tick.

use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// flush when this many items are pending
    pub max_batch: usize,
    /// flush when the oldest pending item has waited this long
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Generic deadline batcher over any item type.
#[derive(Debug)]
pub struct Batcher<T> {
    /// the flush policy (size + deadline)
    pub policy: BatchPolicy,
    pending: Vec<T>,
    oldest: Option<Instant>,
}

impl<T> Batcher<T> {
    /// An empty batcher under the given policy.
    pub fn new(policy: BatchPolicy) -> Batcher<T> {
        Batcher { policy, pending: Vec::new(), oldest: None }
    }

    /// Queue one item (starts the deadline clock when the batch was empty).
    pub fn push(&mut self, item: T) {
        if self.pending.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.pending.push(item);
    }

    /// Items currently pending.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// Whether nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Should the current batch be dispatched now?
    pub fn ready(&self) -> bool {
        if self.pending.is_empty() {
            return false;
        }
        self.pending.len() >= self.policy.max_batch
            || self.oldest.map(|t| t.elapsed() >= self.policy.max_wait).unwrap_or(false)
    }

    /// Time until the deadline fires (for blocking waits); None if empty.
    pub fn time_to_deadline(&self) -> Option<Duration> {
        self.oldest
            .map(|t| self.policy.max_wait.saturating_sub(t.elapsed()))
    }

    /// Blockingly assemble the next batch from `rx`: waits on the channel
    /// (condvar-backed `recv` / `recv_timeout`, never a sleep poll) until
    /// either `max_batch` items are pending or the oldest pending item's
    /// deadline passes, then takes the batch. Items already queued in the
    /// channel are drained without blocking first, so a backlog comes out
    /// as one batch even at `max_wait == 0` (greedy dynamic batching).
    /// Returns `None` only when the channel has disconnected and nothing is
    /// pending; a disconnect with items pending flushes the final partial
    /// batch first.
    pub fn next_batch(&mut self, rx: &mpsc::Receiver<T>) -> Option<Vec<T>> {
        loop {
            // opportunistic drain: whatever is already queued joins the
            // batch with zero waiting
            while self.pending.len() < self.policy.max_batch {
                match rx.try_recv() {
                    Ok(item) => self.push(item),
                    Err(_) => break,
                }
            }
            if self.ready() {
                return Some(self.take());
            }
            match self.time_to_deadline() {
                // nothing pending: block until the first item (or EOF)
                None => match rx.recv() {
                    Ok(item) => self.push(item),
                    Err(mpsc::RecvError) => {
                        return if self.pending.is_empty() { None } else { Some(self.take()) }
                    }
                },
                // batch open: wait at most until its deadline
                Some(wait) => match rx.recv_timeout(wait) {
                    Ok(item) => self.push(item),
                    // deadline fired or sender gone — flush what we have
                    Err(mpsc::RecvTimeoutError::Timeout)
                    | Err(mpsc::RecvTimeoutError::Disconnected) => return Some(self.take()),
                },
            }
        }
    }

    /// Take up to max_batch items.
    pub fn take(&mut self) -> Vec<T> {
        let n = self.pending.len().min(self.policy.max_batch);
        let batch: Vec<T> = self.pending.drain(..n).collect();
        self.oldest = if self.pending.is_empty() {
            None
        } else {
            Some(Instant::now())
        };
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_to_max_batch() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(60) });
        b.push(1);
        b.push(2);
        assert!(!b.ready());
        b.push(3);
        assert!(b.ready());
        assert_eq!(b.take(), vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn deadline_fires_via_blocking_wait() {
        // the condvar/recv_timeout path: no sleep-poll anywhere — the wait
        // returns when the deadline passes, and the partial batch flushes
        let mut b = Batcher::new(BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(1) });
        let (tx, rx) = mpsc::channel::<u32>();
        tx.send(1).unwrap();
        let t0 = Instant::now();
        assert_eq!(b.next_batch(&rx), Some(vec![1]));
        assert!(t0.elapsed() >= Duration::from_millis(1), "flushed before the deadline");
        assert!(b.is_empty());
    }

    #[test]
    fn next_batch_fills_to_max_without_waiting_for_deadline() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(60) });
        let (tx, rx) = mpsc::channel::<u32>();
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        // full batch is ready long before the 60 s deadline
        let t0 = Instant::now();
        assert_eq!(b.next_batch(&rx), Some(vec![0, 1, 2]));
        assert!(t0.elapsed() < Duration::from_secs(5));
        drop(tx);
        // leftovers flush on disconnect; then EOF
        assert_eq!(b.next_batch(&rx), Some(vec![3, 4]));
        assert_eq!(b.next_batch(&rx), None);
    }

    #[test]
    fn next_batch_returns_none_on_empty_disconnect() {
        let mut b: Batcher<u32> = Batcher::new(BatchPolicy::default());
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        assert_eq!(b.next_batch(&rx), None);
    }

    #[test]
    fn next_batch_wakes_on_late_arrivals_from_another_thread() {
        // producer thread trickles items in; the consumer's blocking wait
        // must wake per arrival and flush on the count trigger
        let mut b = Batcher::new(BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(60) });
        let (tx, rx) = mpsc::channel::<u32>();
        let producer = std::thread::spawn(move || {
            for i in 0..4 {
                tx.send(i).unwrap();
                std::thread::sleep(Duration::from_millis(1));
            }
        });
        assert_eq!(b.next_batch(&rx), Some(vec![0, 1, 2, 3]));
        producer.join().unwrap();
    }

    #[test]
    fn take_caps_at_max_batch() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(60) });
        for i in 0..5 {
            b.push(i);
        }
        assert_eq!(b.take(), vec![0, 1]);
        assert_eq!(b.len(), 3);
        assert!(b.ready()); // still >= max_batch
    }

    #[test]
    fn empty_never_ready() {
        let b: Batcher<u32> = Batcher::new(BatchPolicy::default());
        assert!(!b.ready());
        assert!(b.time_to_deadline().is_none());
    }
}
