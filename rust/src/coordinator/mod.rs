//! L3 coordinator: the chip's system-software layer — request routing
//! (dual-mode), dynamic batching, the progressive-search control loop, and
//! serving metrics. PJRT handles are not Send, so a dedicated executor
//! thread owns the engine/backends (leader/worker pattern) and talks to
//! clients over channels.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use metrics::{LatencySummary, ServeMetrics};
pub use request::{CoordStats, Payload, ReplyKind, ReplySink, ReplyTo, Request, Response};
pub use router::{ModePolicy, Router};
pub use server::{BackendSpec, Coordinator, CoordinatorOptions, TrySubmit, WcfeSpec};
