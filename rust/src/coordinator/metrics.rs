//! Serving metrics: latency percentiles, throughput, progressive-search
//! savings — what the serve example and Fig.4/Fig.10 benches report.

use crate::util::stats::percentile_sorted;

/// The latency digest loadgen reports per run and per model.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencySummary {
    /// arithmetic mean (seconds)
    pub mean_s: f64,
    /// median (seconds)
    pub p50_s: f64,
    /// 95th percentile (seconds)
    pub p95_s: f64,
    /// 99th percentile (seconds)
    pub p99_s: f64,
}

/// Per-request serving counters and latency samples (one collector per
/// client thread or per model; [`ServeMetrics::merge`] folds them).
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    /// per-request latency samples (seconds)
    pub latencies_s: Vec<f64>,
    /// per-inference progressive-search segment counts
    pub segments_used: Vec<usize>,
    /// inferences that exited before the last segment
    pub early_exits: u64,
    /// inferences that ran the WCFE (normal mode)
    pub wcfe_runs: u64,
    /// inferences the Confidence policy escalated through the WCFE after
    /// a thin bypass margin (a subset of `wcfe_runs`)
    pub escalations: u64,
    /// summed modeled energy over recorded inferences (joules); 0 when
    /// the responses carried no energy accounting
    pub energy_j: f64,
    /// learn requests served
    pub learns: u64,
    /// failed requests
    pub errors: u64,
    /// requests that never saw a reply within the client's deadline (a
    /// subset of `errors` — timeouts are also counted as errors)
    pub timeouts: u64,
    /// all requests (infer + learn + error)
    pub total: u64,
    /// wall-clock of the whole run (the caller sets it; thread walls
    /// overlap)
    pub wall_s: f64,
}

impl ServeMetrics {
    pub fn record(&mut self, latency_s: f64, segments: usize, early: bool, wcfe: bool) {
        self.latencies_s.push(latency_s);
        self.segments_used.push(segments);
        self.early_exits += u64::from(early);
        self.wcfe_runs += u64::from(wcfe);
        self.total += 1;
    }

    /// An inference with dual-mode accounting: `record` plus the
    /// escalation flag and the modeled per-query energy.
    pub fn record_infer(
        &mut self,
        latency_s: f64,
        segments: usize,
        early: bool,
        wcfe: bool,
        escalated: bool,
        energy_j: f64,
    ) {
        self.record(latency_s, segments, early, wcfe);
        self.escalations += u64::from(escalated);
        self.energy_j += energy_j;
    }

    /// A served learn request (latency tracked, no segments — learning
    /// always encodes the full QHV).
    pub fn record_learn(&mut self, latency_s: f64) {
        self.latencies_s.push(latency_s);
        self.learns += 1;
        self.total += 1;
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
        self.total += 1;
    }

    /// A request that timed out waiting for its reply (counts as an error
    /// too, so error gates catch it).
    pub fn record_timeout(&mut self) {
        self.timeouts += 1;
        self.errors += 1;
        self.total += 1;
    }

    /// Merge another collector (per-client loadgen metrics folded into the
    /// run total; `wall_s` is the caller's to set — thread walls overlap).
    pub fn merge(&mut self, other: &ServeMetrics) {
        self.latencies_s.extend_from_slice(&other.latencies_s);
        self.segments_used.extend_from_slice(&other.segments_used);
        self.early_exits += other.early_exits;
        self.wcfe_runs += other.wcfe_runs;
        self.escalations += other.escalations;
        self.energy_j += other.energy_j;
        self.learns += other.learns;
        self.errors += other.errors;
        self.timeouts += other.timeouts;
        self.total += other.total;
    }

    pub fn throughput_rps(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        self.total as f64 / self.wall_s
    }

    pub fn latency_percentile(&self, p: f64) -> f64 {
        if self.latencies_s.is_empty() {
            return 0.0;
        }
        let mut v = self.latencies_s.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile_sorted(&v, p)
    }

    /// Mean request latency in seconds (0 with no samples).
    pub fn mean_latency(&self) -> f64 {
        if self.latencies_s.is_empty() {
            return 0.0;
        }
        self.latencies_s.iter().sum::<f64>() / self.latencies_s.len() as f64
    }

    /// The mean/p50/p95/p99 digest in one pass (sorts the samples once,
    /// where per-percentile calls re-sort each time).
    pub fn latency_summary(&self) -> LatencySummary {
        if self.latencies_s.is_empty() {
            return LatencySummary::default();
        }
        let mut v = self.latencies_s.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        LatencySummary {
            mean_s: self.mean_latency(),
            p50_s: percentile_sorted(&v, 50.0),
            p95_s: percentile_sorted(&v, 95.0),
            p99_s: percentile_sorted(&v, 99.0),
        }
    }

    pub fn mean_segments(&self) -> f64 {
        if self.segments_used.is_empty() {
            return 0.0;
        }
        self.segments_used.iter().sum::<usize>() as f64 / self.segments_used.len() as f64
    }

    /// Fig.4 complexity-reduction metric over the served traffic.
    pub fn complexity_reduction(&self, total_segments: usize) -> f64 {
        1.0 - self.mean_segments() / total_segments as f64
    }

    /// Inferences answered without the WCFE (total inferences minus
    /// normal-mode runs).
    pub fn bypass_runs(&self) -> u64 {
        self.segments_used.len() as u64 - self.wcfe_runs
    }

    /// Fraction of inferences served in bypass mode (the dual-mode
    /// complexity-saving headline; 0 with no inferences).
    pub fn bypass_fraction(&self) -> f64 {
        let infers = self.segments_used.len() as u64;
        if infers == 0 {
            return 0.0;
        }
        self.bypass_runs() as f64 / infers as f64
    }

    /// Mean modeled energy per inference in joules (0 with no samples).
    pub fn energy_per_query_j(&self) -> f64 {
        let infers = self.segments_used.len();
        if infers == 0 {
            return 0.0;
        }
        self.energy_j / infers as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let mut m = ServeMetrics::default();
        m.record(0.010, 4, true, false);
        m.record(0.020, 8, false, true);
        m.record_error();
        m.wall_s = 1.0;
        assert_eq!(m.total, 3);
        assert_eq!(m.errors, 1);
        assert_eq!(m.early_exits, 1);
        assert!((m.mean_latency() - 0.015).abs() < 1e-12);
        assert!((m.mean_segments() - 6.0).abs() < 1e-12);
        assert!((m.complexity_reduction(8) - 0.25).abs() < 1e-12);
        assert_eq!(m.throughput_rps(), 3.0);
        assert!(m.latency_percentile(95.0) >= m.latency_percentile(50.0));
    }

    #[test]
    fn dual_mode_accounting() {
        let mut m = ServeMetrics::default();
        m.record_infer(0.010, 4, true, false, false, 2.0e-9);
        m.record_infer(0.020, 8, false, true, true, 6.0e-9);
        m.record_infer(0.015, 8, false, true, false, 6.0e-9);
        m.record_learn(0.001);
        assert_eq!(m.wcfe_runs, 2);
        assert_eq!(m.bypass_runs(), 1);
        assert_eq!(m.escalations, 1);
        assert!((m.bypass_fraction() - 1.0 / 3.0).abs() < 1e-12);
        assert!((m.energy_per_query_j() - 14.0e-9 / 3.0).abs() < 1e-20);
        let mut other = ServeMetrics::default();
        other.record_infer(0.010, 4, true, false, false, 2.0e-9);
        other.merge(&m);
        assert_eq!(other.escalations, 1);
        assert!((other.energy_j - 16.0e-9).abs() < 1e-20);
        assert_eq!(other.bypass_runs(), 2);
    }

    #[test]
    fn learns_and_merge() {
        let mut a = ServeMetrics::default();
        a.record(0.010, 4, true, false);
        a.record_learn(0.002);
        let mut b = ServeMetrics::default();
        b.record_learn(0.004);
        b.record_error();
        a.merge(&b);
        assert_eq!(a.total, 4);
        assert_eq!(a.learns, 2);
        assert_eq!(a.errors, 1);
        assert_eq!(a.latencies_s.len(), 3);
        // learn latencies count toward percentiles, not toward segments
        assert_eq!(a.segments_used.len(), 1);
    }

    #[test]
    fn latency_summary_matches_percentile_calls() {
        let mut m = ServeMetrics::default();
        assert_eq!(m.latency_summary(), LatencySummary::default());
        for i in 1..=100 {
            m.record(i as f64 / 1000.0, 4, false, false);
        }
        let s = m.latency_summary();
        assert!((s.mean_s - m.mean_latency()).abs() < 1e-12);
        assert!((s.p50_s - m.latency_percentile(50.0)).abs() < 1e-12);
        assert!((s.p95_s - m.latency_percentile(95.0)).abs() < 1e-12);
        assert!((s.p99_s - m.latency_percentile(99.0)).abs() < 1e-12);
        assert!(s.p50_s <= s.p95_s && s.p95_s <= s.p99_s);
    }
}
