//! Serving metrics: latency percentiles, throughput, progressive-search
//! savings — what the serve example and Fig.4/Fig.10 benches report.

use crate::util::stats::percentile_sorted;

#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub latencies_s: Vec<f64>,
    pub segments_used: Vec<usize>,
    pub early_exits: u64,
    pub wcfe_runs: u64,
    pub learns: u64,
    pub errors: u64,
    pub total: u64,
    pub wall_s: f64,
}

impl ServeMetrics {
    pub fn record(&mut self, latency_s: f64, segments: usize, early: bool, wcfe: bool) {
        self.latencies_s.push(latency_s);
        self.segments_used.push(segments);
        self.early_exits += u64::from(early);
        self.wcfe_runs += u64::from(wcfe);
        self.total += 1;
    }

    /// A served learn request (latency tracked, no segments — learning
    /// always encodes the full QHV).
    pub fn record_learn(&mut self, latency_s: f64) {
        self.latencies_s.push(latency_s);
        self.learns += 1;
        self.total += 1;
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
        self.total += 1;
    }

    /// Merge another collector (per-client loadgen metrics folded into the
    /// run total; `wall_s` is the caller's to set — thread walls overlap).
    pub fn merge(&mut self, other: &ServeMetrics) {
        self.latencies_s.extend_from_slice(&other.latencies_s);
        self.segments_used.extend_from_slice(&other.segments_used);
        self.early_exits += other.early_exits;
        self.wcfe_runs += other.wcfe_runs;
        self.learns += other.learns;
        self.errors += other.errors;
        self.total += other.total;
    }

    pub fn throughput_rps(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        self.total as f64 / self.wall_s
    }

    pub fn latency_percentile(&self, p: f64) -> f64 {
        if self.latencies_s.is_empty() {
            return 0.0;
        }
        let mut v = self.latencies_s.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile_sorted(&v, p)
    }

    pub fn mean_latency(&self) -> f64 {
        if self.latencies_s.is_empty() {
            return 0.0;
        }
        self.latencies_s.iter().sum::<f64>() / self.latencies_s.len() as f64
    }

    pub fn mean_segments(&self) -> f64 {
        if self.segments_used.is_empty() {
            return 0.0;
        }
        self.segments_used.iter().sum::<usize>() as f64 / self.segments_used.len() as f64
    }

    /// Fig.4 complexity-reduction metric over the served traffic.
    pub fn complexity_reduction(&self, total_segments: usize) -> f64 {
        1.0 - self.mean_segments() / total_segments as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let mut m = ServeMetrics::default();
        m.record(0.010, 4, true, false);
        m.record(0.020, 8, false, true);
        m.record_error();
        m.wall_s = 1.0;
        assert_eq!(m.total, 3);
        assert_eq!(m.errors, 1);
        assert_eq!(m.early_exits, 1);
        assert!((m.mean_latency() - 0.015).abs() < 1e-12);
        assert!((m.mean_segments() - 6.0).abs() < 1e-12);
        assert!((m.complexity_reduction(8) - 0.25).abs() < 1e-12);
        assert_eq!(m.throughput_rps(), 3.0);
        assert!(m.latency_percentile(95.0) >= m.latency_percentile(50.0));
    }

    #[test]
    fn learns_and_merge() {
        let mut a = ServeMetrics::default();
        a.record(0.010, 4, true, false);
        a.record_learn(0.002);
        let mut b = ServeMetrics::default();
        b.record_learn(0.004);
        b.record_error();
        a.merge(&b);
        assert_eq!(a.total, 4);
        assert_eq!(a.learns, 2);
        assert_eq!(a.errors, 1);
        assert_eq!(a.latencies_s.len(), 3);
        // learn latencies count toward percentiles, not toward segments
        assert_eq!(a.segments_used.len(), 1);
    }
}
