//! The coordinator proper: a dedicated executor thread owns the (non-Send)
//! backend and serves requests from an MPSC queue — the leader/worker shape
//! the chip's host driver uses. (The PJRT handles are raw C-API pointers;
//! the pure-Rust NativeBackend keeps the same threading model so behavior
//! is identical across backends.)
//!
//! Request path (per Fig.4): route (dual-mode) -> [WCFE] -> quantize ->
//! progressive encode/search loop -> reply. `Learn` payloads go through the
//! gradient-free training path instead.

use crate::config::HdConfig;
use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::coordinator::request::{CoordStats, Payload, ReplySink, ReplyTo, Request, Response};
use crate::coordinator::router::{ModePolicy, Router};
use crate::data::TensorFile;
use crate::energy::DualModeEnergy;
use crate::hdc::wal::Wal;
use crate::hdc::{knowledge, HdBackend, HdClassifier, ProgressiveSearch, SearchMode};
#[cfg(feature = "pjrt")]
use crate::runtime::{Engine, PjrtBackend};
use crate::runtime::{Manifest, NativeBackend};
use crate::sim::{Chip, Mode};
use crate::util::pool::WorkerPool;
use crate::wcfe::{ClusteredWcfe, WcfeModel};
use crate::Result;
use anyhow::Context;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// Which backend the executor thread builds.
#[derive(Clone, Debug)]
pub enum BackendSpec {
    /// pure-Rust NativeBackend, seeded ±1 factors (no artifacts needed)
    Native { cfg: HdConfig, seed: u64 },
    /// pure-Rust NativeBackend with **rematerialized** seed-derived factor
    /// planes: only the plane seeds stay resident; the sign-GEMM kernels
    /// regenerate factor rows on the fly, so large-D registries scale with
    /// models × classes instead of models × D × F
    NativeRemat { cfg: HdConfig, seed: u64 },
    /// pure-Rust NativeBackend with the production factors (and, for image
    /// configs, the software WCFE) from an artifact directory
    NativeArtifacts { artifacts: std::path::PathBuf, config: String },
    /// PJRT over the artifact directory (requires the `pjrt` feature)
    #[cfg(feature = "pjrt")]
    Pjrt { artifacts: std::path::PathBuf, config: String },
}

/// Where the executor's WCFE front-end (normal mode) comes from. The FE
/// always runs the cluster-factored kernel ([`ClusteredWcfe`]) — bit-exact
/// against the naive forward over the same codebook-reconstructed weights,
/// at a fraction of the multiplies.
#[derive(Clone, Debug, Default)]
pub enum WcfeSpec {
    /// no front-end: normal-mode image requests error cleanly
    Disabled,
    /// cluster the dense WCFE weights from the backend's artifact manifest
    /// (when the manifest carries one for an image config; backends without
    /// a manifest simply get no front-end) — the pre-existing artifact path
    #[default]
    Artifacts,
    /// hermetic seeded front-end (the scenario-matrix path): deterministic
    /// He-scaled weights from `seed`, clustered at `clusters` centroids;
    /// `fc_out` is pinned to the serving config's feature count
    Seeded {
        /// square image side in pixels
        image_hw: usize,
        /// image channels
        image_c: usize,
        /// conv-stack output channels, one entry per layer
        channels: Vec<usize>,
        /// codebook size per layer
        clusters: usize,
        /// weight seed (equal seeds ⇒ bit-identical front-ends)
        seed: u64,
    },
}

/// Codebook size used when clustering artifact-loaded WCFE weights.
const ARTIFACT_FE_CLUSTERS: usize = 16;

/// Everything the executor thread needs to build and run one serving model.
#[derive(Clone, Debug)]
pub struct CoordinatorOptions {
    /// which backend the executor thread builds
    pub backend: BackendSpec,
    /// registry identity of this model (empty outside a multi-model
    /// registry). Stamped into knowledge checkpoints and verified on
    /// restore, so model A's checkpoint can never be served as model B's
    /// — even when both share a config geometry.
    pub model: String,
    /// progressive-search confidence threshold
    pub tau: f32,
    /// minimum segments before early exit
    pub min_segments: usize,
    /// default distance kernel (INT8 L1 or bit-packed INT1 Hamming);
    /// individual requests can override it via
    /// [`Payload::FeaturesWithMode`].
    pub search_mode: SearchMode,
    /// dual-mode routing policy (normal/bypass/confidence-escalating)
    pub mode_policy: ModePolicy,
    /// where the WCFE front-end comes from (artifacts, a seeded scenario
    /// model, or disabled)
    pub wcfe: WcfeSpec,
    /// bound on the executor's MPSC request queue
    pub queue_depth: usize,
    /// worker threads the backend may fan out to within one call. `0` (the
    /// serving default) means auto: `CLO_HDNN_THREADS` when set, else all
    /// available cores. The executor thread still owns the backend; this
    /// only shards rows/row-blocks inside a single request.
    pub threads: usize,
    /// default knowledge checkpoint: the target of `Payload::Snapshot(None)`
    /// and of the auto-snapshot cadence below
    pub snapshot_path: Option<std::path::PathBuf>,
    /// auto-snapshot after every N successful learns (0 = explicit
    /// snapshots only; needs `snapshot_path`)
    pub snapshot_every: usize,
    /// warm restart: load this checkpoint into the store before serving
    /// (the file's geometry must match the backend config)
    pub restore_path: Option<std::path::PathBuf>,
    /// durable learn log: append every Learn here **before** applying it,
    /// replay the suffix newer than the restored checkpoint at boot, and
    /// fold the log into the default snapshot on every successful
    /// checkpoint (see [`crate::hdc::wal`])
    pub wal_path: Option<std::path::PathBuf>,
    /// fsync the WAL after every N appended learns (0/1 = every learn is
    /// durable before it is acknowledged — the safe default)
    pub wal_fsync_every: usize,
}

impl CoordinatorOptions {
    /// Hermetic default: a seeded NativeBackend for the given config, with
    /// the worker pool sized to the machine.
    pub fn software(cfg: HdConfig) -> CoordinatorOptions {
        CoordinatorOptions {
            backend: BackendSpec::Native { cfg, seed: 7 },
            model: String::new(),
            tau: 0.5,
            min_segments: 1,
            search_mode: SearchMode::default(),
            mode_policy: ModePolicy::Auto,
            wcfe: WcfeSpec::default(),
            queue_depth: 256,
            threads: 0,
            snapshot_path: None,
            snapshot_every: 0,
            restore_path: None,
            wal_path: None,
            wal_fsync_every: 1,
        }
    }
}

/// The native backend's accepted batch limit — one constant ties it to the
/// executor's batch assembly and Learn-run cap, so every grouped run is
/// guaranteed to fit `encode_full(batch)`.
const NATIVE_MAX_BATCH: usize = 8;

/// Byte budget for one `Payload::WalTail` reply's records: a catching-up
/// follower drains a big backlog over several bounded polls instead of one
/// enormous frame (the wire caps frames at 16 MiB).
const WAL_TAIL_BUDGET: usize = 1024 * 1024;

/// Client handle: submit requests, join on drop.
pub struct Coordinator {
    tx: Option<mpsc::SyncSender<Request>>,
    worker: Option<std::thread::JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Coordinator {
    pub fn start(opts: CoordinatorOptions) -> Result<Coordinator> {
        let (tx, rx) = mpsc::sync_channel::<Request>(opts.queue_depth);
        let (ready_tx, ready_rx) = mpsc::sync_channel::<std::result::Result<(), String>>(1);
        let worker = std::thread::Builder::new()
            .name("clo-hdnn-executor".into())
            .spawn(move || executor_main(opts, rx, ready_tx))?;
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => anyhow::bail!("executor failed to start: {e}"),
            Err(_) => anyhow::bail!("executor thread died during startup"),
        }
        Ok(Coordinator {
            tx: Some(tx),
            worker: Some(worker),
            next_id: std::sync::atomic::AtomicU64::new(1),
        })
    }

    /// Submit and wait (simple synchronous client call).
    pub fn call(&self, payload: Payload) -> Result<Response> {
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        self.tx
            .as_ref()
            .expect("coordinator stopped")
            .send(Request {
                id,
                payload,
                submitted: Instant::now(),
                reply: ReplyTo::Channel(reply_tx),
            })
            .map_err(|_| anyhow::anyhow!("executor gone"))?;
        Ok(reply_rx.recv()?)
    }

    /// Submit without waiting; returns the receiver.
    pub fn submit(&self, payload: Payload) -> Result<mpsc::Receiver<Response>> {
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        self.tx
            .as_ref()
            .expect("coordinator stopped")
            .send(Request {
                id,
                payload,
                submitted: Instant::now(),
                reply: ReplyTo::Channel(reply_tx),
            })
            .map_err(|_| anyhow::anyhow!("executor gone"))?;
        Ok(reply_rx)
    }

    /// Submit with a caller-assigned id and a caller-owned reply channel —
    /// the pipelined serving path. Many requests can share one channel;
    /// the executor answers each as it completes (tagged with `id` and a
    /// [`crate::coordinator::ReplyKind`]), so a connection can keep many
    /// frames in flight and collect replies out of order across models.
    ///
    /// The caller must size `reply` so that every outstanding reply fits:
    /// the executor's send blocks when the channel is full.
    pub fn submit_with(
        &self,
        id: u64,
        payload: Payload,
        reply: mpsc::SyncSender<Response>,
    ) -> Result<()> {
        self.tx
            .as_ref()
            .expect("coordinator stopped")
            .send(Request {
                id,
                payload,
                submitted: Instant::now(),
                reply: ReplyTo::Channel(reply),
            })
            .map_err(|_| anyhow::anyhow!("executor gone"))
    }

    /// Non-blocking submit for the serving reactor: the request carries the
    /// caller's id and completes into `sink` (a [`ReplySink`] never blocks
    /// the executor, so a dead or slow connection cannot stall a model).
    /// When the executor queue is full the payload is handed back so the
    /// caller can defer the frame and retry after a completion drains.
    pub fn try_submit_sink(
        &self,
        id: u64,
        payload: Payload,
        sink: Arc<dyn ReplySink>,
    ) -> std::result::Result<(), TrySubmit> {
        let req = Request { id, payload, submitted: Instant::now(), reply: ReplyTo::Sink(sink) };
        match self.tx.as_ref().expect("coordinator stopped").try_send(req) {
            Ok(()) => Ok(()),
            Err(mpsc::TrySendError::Full(req)) => Err(TrySubmit::Full(req.payload)),
            Err(mpsc::TrySendError::Disconnected(req)) => Err(TrySubmit::Gone(req.payload)),
        }
    }
}

/// Why a [`Coordinator::try_submit_sink`] did not enqueue; both variants
/// hand the payload back to the caller.
#[derive(Debug)]
pub enum TrySubmit {
    /// executor queue full — defer the frame and retry later
    Full(Payload),
    /// executor has shut down — fail the request
    Gone(Payload),
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the queue; executor drains + exits
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Knowledge-persistence bookkeeping on the executor thread.
#[derive(Clone, Debug, Default)]
struct KnowledgeState {
    /// registry identity stamped into checkpoints / verified on restore
    model: String,
    /// default checkpoint target (Snapshot(None) + auto-snapshot)
    snapshot_path: Option<std::path::PathBuf>,
    /// auto-snapshot cadence in learns (0 = off)
    snapshot_every: usize,
    /// learns since the last snapshot (drives the cadence)
    since_snapshot: usize,
    /// snapshots written this process (explicit + auto)
    snapshots: u64,
    /// consecutive auto-snapshot failures: the warning is emitted only when
    /// this is a power of two (1, 2, 4, 8, …), so a full disk warns with
    /// exponential backoff instead of flooding stderr at learn rate
    snapshot_fail_streak: u64,
}

/// Dual-mode serving counters maintained by the executor and reported in
/// [`CoordStats`].
#[derive(Clone, Copy, Debug, Default)]
struct ModeCounters {
    /// classifications answered without the WCFE
    bypass: u64,
    /// classifications answered through the WCFE
    normal: u64,
    /// Confidence-policy bypass-first classifications re-run through the
    /// WCFE after a thin top-2 margin
    escalations: u64,
}

/// Executor state living on the worker thread.
struct Executor {
    classifier: HdClassifier,
    router: Router,
    /// cluster-factored WCFE front-end (normal mode); `None` means image
    /// requests can only be served under a bypass route
    fe: Option<ClusteredWcfe>,
    /// worker-pool budget for batched feature extraction (contiguous
    /// normal-mode image runs fan out one image per scoped thread)
    fe_pool: WorkerPool,
    image_elems: usize,
    /// per-query energy/ops accounting (chip datapath op counts priced by
    /// the calibrated energy model at 0.7 V)
    energy: DualModeEnergy,
    /// dual-mode counters Stats replies surface
    modes: ModeCounters,
    /// largest Learn run the backend can encode in one call (1 disables
    /// grouped learning — the PJRT path is lowered at batch 1)
    learn_batch_cap: usize,
    knowledge: KnowledgeState,
    /// durable learn log: every Learn is appended (and per the fsync
    /// cadence, durable) here before it touches the store
    wal: Option<Wal>,
    /// promotion generation (mirrors the WAL segment header when a WAL is
    /// kept; tracked in memory otherwise so fencing still works)
    epoch: u64,
}

fn executor_main(
    opts: CoordinatorOptions,
    rx: mpsc::Receiver<Request>,
    ready: mpsc::SyncSender<std::result::Result<(), String>>,
) {
    let built = build_executor(&opts);
    let mut ex = match built {
        Ok(ex) => {
            let _ = ready.send(Ok(()));
            ex
        }
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return;
        }
    };
    // Event-driven batch assembly (no sleep polling): the batcher blocks on
    // the request channel and greedily drains any backlog into one batch —
    // zero added latency for a lone request (max_wait = 0, so a singleton
    // flushes immediately), one wakeup per burst under load. Within a
    // batch, contiguous runs of Learn requests are encoded in ONE backend
    // call (the b8 dispatch amortization); everything else is handled per
    // request, in arrival order, with per-request replies either way.
    let mut batcher: Batcher<Request> = Batcher::new(BatchPolicy {
        max_batch: NATIVE_MAX_BATCH,
        max_wait: std::time::Duration::ZERO,
    });
    while let Some(batch) = batcher.next_batch(&rx) {
        let mut i = 0usize;
        while i < batch.len() {
            let mut j = i;
            while j < batch.len()
                && j - i < ex.learn_batch_cap
                && matches!(batch[j].payload, Payload::Learn(..))
            {
                j += 1;
            }
            if j - i >= 2 {
                ex.handle_learn_run(&batch[i..j]);
                i = j;
                continue;
            }
            // contiguous normal-mode image classifications: one batched
            // feature extraction through the worker pool, then per-request
            // classify/replies in arrival order
            let mut j = i;
            while j < batch.len() && ex.image_batchable(&batch[j].payload) {
                j += 1;
            }
            if j - i >= 2 {
                ex.handle_image_run(&batch[i..j]);
                i = j;
                continue;
            }
            let req = &batch[i];
            let resp = ex.handle(req);
            let _ = req
                .reply
                .send(resp.unwrap_or_else(|e| Response::error(req.id, format!("{e:#}"))));
            i += 1;
        }
    }
    // graceful shutdown: if an auto-snapshot cadence is configured and
    // learns landed since the last checkpoint, persist them on the way out
    ex.final_snapshot();
}

/// Load the software WCFE model if the manifest carries one for an image
/// config; returns `(model, image_elems)`.
fn load_native_wcfe(manifest: &Manifest, config: &str) -> Result<(Option<WcfeModel>, usize)> {
    match &manifest.wcfe {
        Some(meta) if manifest.config(config)?.image => {
            let tf = TensorFile::load(manifest.dir.join(&meta.weights))?;
            let model = WcfeModel::load(
                &tf,
                &meta.channels,
                meta.fc_out,
                meta.image_hw,
                meta.image_c,
            )?;
            Ok((Some(model), meta.image_hw * meta.image_hw * meta.image_c))
        }
        _ => Ok((None, 0)),
    }
}

/// Build the cluster-factored FE stage per the [`WcfeSpec`]; returns
/// `(fe, image_elems)`.
fn build_fe(
    spec: &WcfeSpec,
    manifest: Option<(&Manifest, &str)>,
    cfg: &HdConfig,
) -> Result<(Option<ClusteredWcfe>, usize)> {
    match spec {
        WcfeSpec::Disabled => Ok((None, 0)),
        WcfeSpec::Artifacts => match manifest {
            Some((m, config)) => {
                let (model, image_elems) = load_native_wcfe(m, config)?;
                Ok((
                    model.map(|m| ClusteredWcfe::cluster(m, ARTIFACT_FE_CLUSTERS)),
                    image_elems,
                ))
            }
            None => Ok((None, 0)),
        },
        WcfeSpec::Seeded { image_hw, image_c, channels, clusters, seed } => {
            if channels.is_empty() {
                anyhow::bail!("seeded WCFE needs at least one conv layer");
            }
            let pooled = image_hw >> channels.len();
            if pooled == 0 || image_hw % (1 << channels.len()) != 0 {
                anyhow::bail!(
                    "seeded WCFE: image side {image_hw} does not survive {} maxpool halvings",
                    channels.len()
                );
            }
            let model =
                WcfeModel::seeded(*image_hw, *image_c, channels, cfg.features(), *seed);
            Ok((
                Some(ClusteredWcfe::cluster(model, (*clusters).max(1))),
                image_hw * image_hw * image_c,
            ))
        }
    }
}

fn build_executor(opts: &CoordinatorOptions) -> Result<Executor> {
    let policy = ProgressiveSearch {
        tau: opts.tau,
        min_segments: opts.min_segments,
        mode: opts.search_mode,
    };
    let router = Router { policy: opts.mode_policy };
    // backend + (for artifact specs) the manifest the FE may come from
    let (classifier, learn_batch_cap, manifest) = match &opts.backend {
        BackendSpec::Native { cfg, seed } => (
            HdClassifier::new(
                Box::new(NativeBackend::seeded(cfg.clone(), *seed, NATIVE_MAX_BATCH)?),
                policy,
            ),
            NATIVE_MAX_BATCH,
            None,
        ),
        BackendSpec::NativeRemat { cfg, seed } => (
            HdClassifier::new(
                Box::new(NativeBackend::seeded_remat(cfg.clone(), *seed, NATIVE_MAX_BATCH)?),
                policy,
            ),
            NATIVE_MAX_BATCH,
            None,
        ),
        BackendSpec::NativeArtifacts { artifacts, config } => {
            let manifest = Manifest::load(artifacts)?;
            let backend = NativeBackend::from_manifest(&manifest, config, NATIVE_MAX_BATCH)?;
            (
                HdClassifier::new(Box::new(backend), policy),
                NATIVE_MAX_BATCH,
                Some((manifest, config.clone())),
            )
        }
        #[cfg(feature = "pjrt")]
        BackendSpec::Pjrt { artifacts, config } => {
            let manifest = Manifest::load(artifacts)?;
            let mut engine = Engine::load(artifacts)?;
            let backend = PjrtBackend::new(&mut engine, config, 1)?;
            (
                HdClassifier::new(Box::new(backend), policy),
                1,
                Some((manifest, config.clone())),
            )
        }
    };
    let (fe, image_elems) = build_fe(
        &opts.wcfe,
        manifest.as_ref().map(|(m, c)| (m, c.as_str())),
        classifier.cfg(),
    )?;
    // price the datapaths once: HDC encode+search ops per progressive
    // segment from the chip formulas, FE ops from the clustered stack
    let chip = Chip::default();
    let hdc_ops =
        chip.encode_segment_ops(classifier.cfg()) + chip.search_segment_ops(classifier.cfg());
    let (fe_ops, fe_dense_ops) = fe
        .as_ref()
        .map(|f| (f.clustered_ops(), f.dense_ops()))
        .unwrap_or((0, 0));
    let mut ex = Executor {
        classifier,
        router,
        fe,
        fe_pool: WorkerPool::new(opts.threads),
        image_elems,
        energy: DualModeEnergy::new(hdc_ops, fe_ops, fe_dense_ops, 0.7),
        modes: ModeCounters::default(),
        learn_batch_cap,
        knowledge: KnowledgeState::default(),
        wal: None,
        epoch: 0,
    };
    // size the backend's per-call worker pool (0 = all cores); backends
    // without an internal pool ignore the hint
    ex.classifier.backend_mut().set_parallelism(opts.threads);
    ex.knowledge = KnowledgeState {
        model: opts.model.clone(),
        snapshot_path: opts.snapshot_path.clone(),
        snapshot_every: opts.snapshot_every,
        since_snapshot: 0,
        snapshots: 0,
        snapshot_fail_streak: 0,
    };
    // warm restart: swap in the checkpointed store before any request runs
    if let Some(path) = &opts.restore_path {
        ex.restore_store(path)?;
    }
    // crash recovery: open (or create) the learn log and replay the suffix
    // newer than whatever the restore landed — commutative bundling through
    // the same deterministic backend makes the replayed store bit-identical
    // to the acknowledged prefix the log holds
    if let Some(path) = &opts.wal_path {
        let (features, classes) =
            (ex.classifier.cfg().features(), ex.classifier.cfg().classes);
        let have = ex.classifier.store.total_learns();
        let mut wal = Wal::open(
            path,
            &opts.model,
            features,
            classes,
            have,
            opts.wal_fsync_every,
        )?;
        if wal.base_seq() > have {
            anyhow::bail!(
                "WAL segment {} starts at learn {} but the restored knowledge \
                 holds only {have}: the learns in between are gone — restore \
                 the snapshot the log was rotated against",
                path.display(),
                wal.base_seq()
            );
        }
        let mut replayed = 0usize;
        for rec in wal.records() {
            if rec.seq <= have {
                continue; // the snapshot already folded this learn in
            }
            ex.classifier
                .learn(&rec.features, rec.class as usize)
                .with_context(|| format!("replay WAL learn {}", rec.seq))?;
            replayed += 1;
        }
        if have > wal.last_seq() {
            // the checkpoint is newer than the whole log (e.g. a shutdown
            // flush landed after the last rotation): fold and move on
            wal.rotate(have)?;
        }
        if ex.classifier.store.total_learns() != wal.last_seq() {
            anyhow::bail!(
                "WAL replay desync: store holds {} learns but {} ends at seq {}",
                ex.classifier.store.total_learns(),
                path.display(),
                wal.last_seq()
            );
        }
        if replayed > 0 {
            eprintln!(
                "recovered {replayed} learn(s) from {} (store now holds {})",
                path.display(),
                ex.classifier.store.total_learns()
            );
            // the replayed learns are not in any checkpoint yet; let the
            // auto-snapshot cadence fold them
            ex.knowledge.since_snapshot = replayed;
        }
        // a restarted promoted primary resumes its sealed generation
        ex.epoch = wal.epoch();
        ex.wal = Some(wal);
    }
    Ok(ex)
}

impl Executor {
    /// Replace the live store with a checkpoint, refusing model-identity,
    /// geometry, or calibration drift (any of which would serve silently
    /// wrong answers).
    fn restore_store(&mut self, path: &std::path::Path) -> Result<()> {
        let (store, model) = knowledge::load_named(path)?;
        self.install_store(store, &model, &path.display().to_string())
    }

    /// Replace the live store with an in-memory CLOK image (a follower
    /// bootstrapping from `Payload::SnapshotFetch` bytes); same checks as
    /// [`Executor::restore_store`].
    fn restore_image(&mut self, bytes: &[u8]) -> Result<()> {
        let (store, model) = knowledge::from_bytes_named(bytes)?;
        self.install_store(store, &model, "snapshot image")
    }

    /// The shared tail of restore: verify identity/geometry/calibration,
    /// swap the store in, and re-anchor the learn log at the new state.
    fn install_store(
        &mut self,
        store: crate::hdc::ChvStore,
        model: &str,
        origin: &str,
    ) -> Result<()> {
        if !model.is_empty()
            && !self.knowledge.model.is_empty()
            && model != self.knowledge.model
        {
            anyhow::bail!(
                "knowledge checkpoint {origin} belongs to model '{model}' \
                 (this executor serves model '{}')",
                self.knowledge.model
            );
        }
        if !knowledge::compatible(store.cfg(), self.classifier.cfg()) {
            anyhow::bail!(
                "knowledge checkpoint {origin} was trained for config '{}' \
                 (geometry differs from serving config '{}')",
                store.cfg().name,
                self.classifier.cfg().name
            );
        }
        if !knowledge::calibration_matches(store.cfg(), self.classifier.cfg()) {
            let (a, b) = (store.cfg(), self.classifier.cfg());
            anyhow::bail!(
                "knowledge checkpoint {origin} was calibrated differently \
                 (qbits/scale_x/scale_q {}/{}/{} vs serving {}/{}/{}): \
                 its class hypervectors are incommensurable with queries \
                 quantized under the serving config — re-train or restore \
                 into a matching config",
                a.qbits,
                a.scale_x,
                a.scale_q,
                b.qbits,
                b.scale_x,
                b.scale_q
            );
        }
        self.classifier.store = store;
        // the live store now equals a checkpoint: nothing is unsaved
        self.knowledge.since_snapshot = 0;
        // the old log's seq numbering no longer matches the store; restart
        // the segment at the restored learn count. Rotation failure would
        // leave a log that desyncs replay, so it disables durable logging
        // (loudly) rather than risking a wrong recovery.
        let total = self.classifier.store.total_learns();
        let rotate_err = match self.wal.as_mut() {
            Some(wal) => wal.rotate(total).err(),
            None => None,
        };
        if let Some(e) = rotate_err {
            eprintln!(
                "WAL could not be re-anchored after restore; durable logging \
                 disabled for this process: {e:#}"
            );
            self.wal = None;
        }
        Ok(())
    }

    /// Persist the store to `path` (or the configured default) atomically.
    fn snapshot_store(&mut self, path: Option<&std::path::Path>) -> Result<std::path::PathBuf> {
        let target: std::path::PathBuf = match path {
            Some(p) => p.to_path_buf(),
            None => self
                .knowledge
                .snapshot_path
                .clone()
                .ok_or_else(|| {
                    anyhow::anyhow!("snapshot: no path given and no default configured")
                })?,
        };
        knowledge::save_named(&self.classifier.store, &target, &self.knowledge.model)?;
        self.knowledge.snapshots += 1;
        self.knowledge.since_snapshot = 0;
        self.knowledge.snapshot_fail_streak = 0;
        // compaction: a snapshot at the default path is what a restart
        // restores from, so the log up to here is redundant — fold it.
        // (A snapshot anywhere else must NOT rotate: the default
        // checkpoint on disk still predates the fold point, and recovery
        // restores from it.) Rotation failure is benign for correctness —
        // replay skips records the snapshot already holds — so it only
        // warns.
        if self.knowledge.snapshot_path.as_deref() == Some(target.as_path()) {
            let total = self.classifier.store.total_learns();
            if let Some(wal) = self.wal.as_mut() {
                if let Err(e) = wal.rotate(total) {
                    eprintln!(
                        "WAL rotation after snapshot failed (log keeps \
                         growing; recovery unaffected): {e:#}"
                    );
                }
            }
        }
        Ok(target)
    }

    /// Record `n` successful learns and run the auto-snapshot cadence. A
    /// failed auto-snapshot must not take down serving: it is retried after
    /// the next learn, and reported on stderr with exponential backoff
    /// (consecutive-failure streaks warn at 1, 2, 4, 8, …) so a full disk
    /// cannot flood the log at learn rate.
    fn note_learns(&mut self, n: usize) {
        self.knowledge.since_snapshot += n;
        if self.knowledge.snapshot_every == 0
            || self.knowledge.since_snapshot < self.knowledge.snapshot_every
            || self.knowledge.snapshot_path.is_none()
        {
            return;
        }
        if let Err(e) = self.snapshot_store(None) {
            self.knowledge.snapshot_fail_streak += 1;
            let streak = self.knowledge.snapshot_fail_streak;
            if streak.is_power_of_two() {
                eprintln!(
                    "auto-snapshot failed (attempt {streak}; serving \
                     continues): {e:#}"
                );
            }
        }
    }

    /// Shutdown flush: any acknowledged learns still inside the WAL's fsync
    /// cadence window are flushed, and — when a snapshot path is configured
    /// — learns not yet checkpointed are persisted on graceful shutdown,
    /// with or without an auto-snapshot cadence.
    fn final_snapshot(&mut self) {
        if let Some(wal) = self.wal.as_mut() {
            if let Err(e) = wal.sync() {
                eprintln!("shutdown WAL flush failed: {e:#}");
            }
        }
        if self.knowledge.since_snapshot == 0 || self.knowledge.snapshot_path.is_none() {
            return;
        }
        if let Err(e) = self.snapshot_store(None) {
            eprintln!("shutdown snapshot failed: {e:#}");
        }
    }

    /// The knowledge counters STATS and WAL-TAIL replies carry.
    fn coord_stats(&self) -> CoordStats {
        CoordStats {
            learns: self.classifier.store.total_learns(),
            trained_classes: self.classifier.store.trained_classes(),
            snapshots: self.knowledge.snapshots,
            learn_seq: self
                .wal
                .as_ref()
                .map_or(self.classifier.store.total_learns(), |w| w.last_seq()),
            bypass: self.modes.bypass,
            normal: self.modes.normal,
            escalations: self.modes.escalations,
            policy: self.router.policy.code(),
            policy_margin: self.router.policy.margin(),
            epoch: self.epoch,
        }
    }

    /// Follower promotion: seal the inherited log position and step into
    /// the next generation. With a WAL the seal is durable **before** the
    /// in-memory epoch commits (a crash between the two recovers the
    /// sealed epoch from the segment header); without one the epoch is
    /// tracked in memory so fencing still works for the process lifetime.
    fn promote(&mut self, min_epoch: u64) -> Result<()> {
        let next = self.epoch.max(min_epoch) + 1;
        let sealed = self.classifier.store.total_learns();
        if let Some(wal) = self.wal.as_mut() {
            wal.rotate_to(sealed, next)
                .context("promote: seal the WAL under the new epoch")?;
        }
        self.epoch = next;
        Ok(())
    }

    /// One batched encode for a contiguous run of Learn requests, then
    /// per-class bundling in arrival order and per-request replies.
    /// Bit-identical to handling each Learn individually
    /// (`HdClassifier::learn_batch`'s contract).
    ///
    /// A malformed request (wrong feature length, class out of range) gets
    /// its own error reply and is dropped from the run **before** the
    /// batched encode, so it can never poison valid neighbors — and
    /// because validation rules out every `store.update` failure mode, an
    /// encode error (the only remaining one) happens before any store
    /// mutation: error replies and store state always agree.
    fn handle_learn_run(&mut self, run: &[Request]) {
        let t0 = Instant::now();
        let (feat, classes) =
            (self.classifier.cfg().features(), self.classifier.cfg().classes);
        let mut samples: Vec<(&[f32], usize)> = Vec::with_capacity(run.len());
        let mut valid: Vec<&Request> = Vec::with_capacity(run.len());
        for r in run {
            let (x, class) = match &r.payload {
                Payload::Learn(x, class) => (x.as_slice(), *class),
                _ => unreachable!("executor groups only Learn payloads"),
            };
            if x.len() != feat {
                let msg = format!("learn: features len {} != F {feat}", x.len());
                let _ = r.reply.send(Response::error(r.id, msg));
            } else if class >= classes {
                let msg = format!("learn: class {class} out of range (< {classes})");
                let _ = r.reply.send(Response::error(r.id, msg));
            } else {
                samples.push((x, class));
                valid.push(r);
            }
        }
        if valid.is_empty() {
            return;
        }
        // WAL-before-apply: the whole validated run is logged (and, per the
        // fsync cadence, durable) before any of it touches the store; a
        // failed append errors the run with the store untouched, so error
        // replies, store state, and the log always agree
        if let Some(wal) = self.wal.as_mut() {
            let items: Vec<(u32, &[f32])> =
                samples.iter().map(|&(x, class)| (class as u32, x)).collect();
            if let Err(e) = wal.append_batch(&items) {
                let msg = format!("learn: wal append: {e:#}");
                for r in &valid {
                    let _ = r.reply.send(Response::error(r.id, msg.clone()));
                }
                return;
            }
        }
        let result = self.classifier.learn_batch(&samples);
        if result.is_err() {
            // compensate: the logged run never reached the store, so a
            // replay must not include it
            if let Some(wal) = self.wal.as_mut() {
                if let Err(e) = wal.rollback(samples.len()) {
                    eprintln!("WAL rollback after failed learn run: {e:#}");
                }
            }
        }
        let segments = self.classifier.cfg().segments;
        for (r, (_, class)) in valid.iter().zip(&samples) {
            let resp = match &result {
                Ok(()) => Response {
                    kind: crate::coordinator::ReplyKind::Learn,
                    class: Some(*class),
                    segments_used: segments,
                    latency_s: t0.elapsed().as_secs_f64(),
                    ..Response::ok(r.id)
                },
                Err(e) => Response::error(r.id, format!("{e:#}")),
            };
            let _ = r.reply.send(resp);
        }
        if result.is_ok() {
            self.note_learns(valid.len());
        }
    }

    fn extract_features(&mut self, img: &[f32]) -> Result<Vec<f32>> {
        let fe = self.fe.as_ref().ok_or_else(|| {
            anyhow::anyhow!("normal mode needs a WCFE front-end (artifacts or a seeded spec)")
        })?;
        if img.len() != self.image_elems {
            anyhow::bail!("image has {} elems, expected {}", img.len(), self.image_elems);
        }
        fe.forward(img)
    }

    /// True when the payload is an image classification the router sends
    /// through the FE up front — the grouping predicate for batched
    /// extraction (Confidence starts in bypass, so it never batches here).
    fn image_batchable(&self, payload: &Payload) -> bool {
        matches!(payload, Payload::Image(_) | Payload::ImageWithMode(..))
            && self.fe.is_some()
            && self.router.route(payload) == Mode::Normal
    }

    /// A contiguous run of normal-mode image classifications: one batched
    /// feature extraction fanned out over the worker pool, then the usual
    /// per-request classify + reply in arrival order. Per-image results are
    /// bit-identical to the singleton path; a bad image errors alone.
    fn handle_image_run(&mut self, run: &[Request]) {
        let t0 = Instant::now();
        let imgs: Vec<&[f32]> = run
            .iter()
            .map(|r| match &r.payload {
                Payload::Image(img) | Payload::ImageWithMode(img, _) => img.as_slice(),
                _ => unreachable!("image_batchable gates this run"),
            })
            .collect();
        let expected = self.image_elems;
        let features: Vec<Result<Vec<f32>>> = match self.fe.as_ref() {
            Some(fe) => fe.forward_batch(&imgs, &self.fe_pool),
            None => unreachable!("image_batchable requires an FE"),
        };
        for (r, (img, feats)) in run.iter().zip(imgs.iter().zip(features)) {
            let over = match &r.payload {
                Payload::ImageWithMode(_, m) => Some(*m),
                _ => None,
            };
            let resp = (|| -> Result<Response> {
                if img.len() != expected {
                    anyhow::bail!("image has {} elems, expected {expected}", img.len());
                }
                let res = self.classify_with(&feats?, over)?;
                self.modes.normal += 1;
                Ok(self.classify_response(r.id, &res, true, false, t0))
            })();
            let _ = r
                .reply
                .send(resp.unwrap_or_else(|e| Response::error(r.id, format!("{e:#}"))));
        }
    }

    /// One classification with an optional per-request search-mode
    /// override: swap the policy's kernel for this call, then restore it.
    fn classify_with(
        &mut self,
        features: &[f32],
        over: Option<SearchMode>,
    ) -> Result<crate::hdc::ProgressiveResult> {
        let default_mode = self.classifier.policy.mode;
        if let Some(m) = over {
            self.classifier.policy.mode = m;
        }
        let r = self.classifier.classify(features);
        self.classifier.policy.mode = default_mode;
        r
    }

    /// Assemble a classify reply with dual-mode flags + energy accounting.
    fn classify_response(
        &self,
        id: u64,
        r: &crate::hdc::ProgressiveResult,
        used_wcfe: bool,
        escalated: bool,
        t0: Instant,
    ) -> Response {
        Response {
            class: Some(r.class),
            segments_used: r.segments_used,
            early_exit: r.early_exit,
            used_wcfe,
            escalated,
            energy_j: self.energy.query_energy_j(r.segments_used, used_wcfe),
            latency_s: t0.elapsed().as_secs_f64(),
            ..Response::ok(id)
        }
    }

    /// The shared learn path (`Learn` carries features; `LearnImage` lands
    /// here after extraction): validate, WAL-append, bundle, reply.
    fn do_learn(&mut self, id: u64, t0: Instant, x: &[f32], class: usize) -> Result<Response> {
        // validate before the WAL append: a record the log accepts must
        // always be replayable
        let (feat, classes) =
            (self.classifier.cfg().features(), self.classifier.cfg().classes);
        if x.len() != feat {
            anyhow::bail!("learn: features len {} != F {feat}", x.len());
        }
        if class >= classes {
            anyhow::bail!("learn: class {class} out of range (< {classes})");
        }
        if let Some(wal) = self.wal.as_mut() {
            wal.append(class as u32, x).context("learn: wal append")?;
        }
        if let Err(e) = self.classifier.learn(x, class) {
            // compensate: the logged learn never reached the store
            if let Some(wal) = self.wal.as_mut() {
                if let Err(re) = wal.rollback(1) {
                    eprintln!("WAL rollback after failed learn: {re:#}");
                }
            }
            return Err(e);
        }
        self.note_learns(1);
        Ok(Response {
            kind: crate::coordinator::ReplyKind::Learn,
            class: Some(class),
            segments_used: self.classifier.cfg().segments,
            latency_s: t0.elapsed().as_secs_f64(),
            ..Response::ok(id)
        })
    }

    fn handle(&mut self, req: &Request) -> Result<Response> {
        let t0 = Instant::now();
        match &req.payload {
            Payload::Learn(x, class) => self.do_learn(req.id, t0, x, *class),
            Payload::LearnImage(img, class) => {
                // the fix for image learns: under Auto (and Confidence) the
                // router sends raw-pixel learns through the FE, so the
                // bundled sample lives in the same feature space queries are
                // answered in; ForceBypass bundles the pixels directly. The
                // WAL logs the post-extraction features either way — replay
                // and replication stay pure feature-space operations.
                let x = match self.router.route(&req.payload) {
                    Mode::Normal => self.extract_features(img)?,
                    Mode::Bypass => img.clone(),
                };
                self.do_learn(req.id, t0, &x, *class)
            }
            Payload::Snapshot(path) => {
                let target = self.snapshot_store(path.as_deref())?;
                Ok(Response {
                    kind: crate::coordinator::ReplyKind::Snapshot,
                    detail: Some(target.display().to_string()),
                    latency_s: t0.elapsed().as_secs_f64(),
                    ..Response::ok(req.id)
                })
            }
            Payload::Restore(path) => {
                self.restore_store(path)?;
                Ok(Response {
                    kind: crate::coordinator::ReplyKind::Restore,
                    detail: Some(path.display().to_string()),
                    latency_s: t0.elapsed().as_secs_f64(),
                    ..Response::ok(req.id)
                })
            }
            Payload::RestoreImage(bytes) => {
                self.restore_image(bytes)?;
                Ok(Response {
                    kind: crate::coordinator::ReplyKind::Restore,
                    detail: Some(format!("image ({} bytes)", bytes.len())),
                    latency_s: t0.elapsed().as_secs_f64(),
                    ..Response::ok(req.id)
                })
            }
            Payload::Stats => Ok(Response {
                kind: crate::coordinator::ReplyKind::Stats,
                stats: Some(self.coord_stats()),
                latency_s: t0.elapsed().as_secs_f64(),
                ..Response::ok(req.id)
            }),
            Payload::WalTail { after } => {
                let wal = self.wal.as_ref().ok_or_else(|| {
                    anyhow::anyhow!(
                        "wal-tail: this model keeps no learn log (serve with --wal)"
                    )
                })?;
                if *after < wal.base_seq() {
                    anyhow::bail!(
                        "wal-tail: learns up to {} were compacted into a snapshot \
                         (caller is at {after}); bootstrap again with snapshot-fetch",
                        wal.base_seq()
                    );
                }
                // cap one reply's record bytes so a huge backlog streams in
                // bounded frames over several polls; the first record always
                // goes through
                let mut records = Vec::new();
                let mut budget = WAL_TAIL_BUDGET;
                for r in wal.records() {
                    if r.seq <= *after {
                        continue;
                    }
                    let cost = 16 + 4 * r.features.len();
                    if !records.is_empty() && cost > budget {
                        break;
                    }
                    budget = budget.saturating_sub(cost);
                    records.push(r.clone());
                }
                Ok(Response {
                    kind: crate::coordinator::ReplyKind::WalTail,
                    records: Some(records),
                    wal_base: Some(wal.base_seq()),
                    stats: Some(self.coord_stats()),
                    latency_s: t0.elapsed().as_secs_f64(),
                    ..Response::ok(req.id)
                })
            }
            Payload::Promote { min_epoch } => {
                self.promote(min_epoch)?;
                Ok(Response {
                    kind: crate::coordinator::ReplyKind::Promote,
                    stats: Some(self.coord_stats()),
                    detail: Some(format!(
                        "promoted to epoch {} at learn {}",
                        self.epoch,
                        self.classifier.store.total_learns()
                    )),
                    latency_s: t0.elapsed().as_secs_f64(),
                    ..Response::ok(req.id)
                })
            }
            Payload::SnapshotFetch => Ok(Response {
                kind: crate::coordinator::ReplyKind::SnapshotImage,
                image: Some(knowledge::to_bytes_named(
                    &self.classifier.store,
                    &self.knowledge.model,
                )),
                stats: Some(self.coord_stats()),
                latency_s: t0.elapsed().as_secs_f64(),
                ..Response::ok(req.id)
            }),
            payload => {
                let mut mode = self.router.route(payload);
                let mut forced_escalation = false;
                // Confidence serves images bypass-first, which feeds raw
                // pixels to the encoder — only well-formed when the image
                // has exactly F elements. When the geometry rules bypass
                // out, the request escalates unconditionally (identical to
                // ForceNormal), rather than erroring on a doomed first pass.
                if let (
                    ModePolicy::Confidence { .. },
                    Payload::Image(img) | Payload::ImageWithMode(img, _),
                    Mode::Bypass,
                ) = (self.router.policy, payload, mode)
                {
                    if img.len() != self.classifier.cfg().features() && self.fe.is_some() {
                        mode = Mode::Normal;
                        forced_escalation = true;
                    }
                }
                // `escalatable` keeps the raw pixels around when a
                // Confidence policy serves an image bypass-first: a thin
                // margin re-runs exactly the ForceNormal path on them
                let (features, used_wcfe, search_override, escalatable) =
                    match (payload, mode) {
                        (Payload::Image(img), Mode::Normal) => {
                            (self.extract_features(img)?, true, None, None)
                        }
                        (Payload::Image(img), Mode::Bypass) => {
                            (img.clone(), false, None, Some(img))
                        }
                        (Payload::ImageWithMode(img, m), Mode::Normal) => {
                            (self.extract_features(img)?, true, Some(*m), None)
                        }
                        (Payload::ImageWithMode(img, m), Mode::Bypass) => {
                            (img.clone(), false, Some(*m), Some(img))
                        }
                        (Payload::Features(x), _) => (x.clone(), false, None, None),
                        (Payload::FeaturesWithMode(x, m), _) => {
                            (x.clone(), false, Some(*m), None)
                        }
                        _ => unreachable!("learn/snapshot/restore/stats/wal ops handled above"),
                    };
                let mut used_wcfe = used_wcfe;
                let mut escalated = forced_escalation;
                let mut first_pass_segments = 0usize;
                let mut r = self.classify_with(&features, search_override)?;
                if let (ModePolicy::Confidence { margin }, Some(img), false) =
                    (self.router.policy, escalatable, used_wcfe)
                {
                    if r.margin < margin && self.fe.is_some() {
                        first_pass_segments = r.segments_used;
                        let features = self.extract_features(img)?;
                        r = self.classify_with(&features, search_override)?;
                        used_wcfe = true;
                        escalated = true;
                    }
                }
                if used_wcfe {
                    self.modes.normal += 1;
                } else {
                    self.modes.bypass += 1;
                }
                self.modes.escalations += u64::from(escalated);
                let mut resp = self.classify_response(req.id, &r, used_wcfe, escalated, t0);
                if first_pass_segments > 0 {
                    // the query really ran twice: the abandoned bypass pass
                    // is paid for on top of the normal-mode re-run (a
                    // geometry-forced escalation never ran a first pass)
                    resp.energy_j += self.energy.query_energy_j(first_pass_segments, false);
                }
                Ok(resp)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn proto_and_coordinator() -> (Coordinator, Vec<Vec<f32>>) {
        let cfg = HdConfig::synthetic("t", 8, 8, 32, 32, 8, 4);
        let coord = Coordinator::start(CoordinatorOptions::software(cfg.clone())).unwrap();
        let mut rng = Rng::new(91);
        let protos: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..cfg.features()).map(|_| rng.normal_f32() * 40.0).collect())
            .collect();
        (coord, protos)
    }

    #[test]
    fn learn_then_classify_through_channels() {
        let (coord, protos) = proto_and_coordinator();
        for (c, p) in protos.iter().enumerate() {
            for _ in 0..3 {
                let r = coord.call(Payload::Learn(p.clone(), c)).unwrap();
                assert!(r.error.is_none());
            }
        }
        for (c, p) in protos.iter().enumerate() {
            let r = coord.call(Payload::Features(p.clone())).unwrap();
            assert_eq!(r.class, Some(c));
            assert!(r.latency_s > 0.0);
            assert!(!r.used_wcfe);
        }
    }

    #[test]
    fn async_submission_order_independent() {
        let (coord, protos) = proto_and_coordinator();
        for (c, p) in protos.iter().enumerate() {
            coord.call(Payload::Learn(p.clone(), c)).unwrap();
        }
        let rxs: Vec<_> = protos
            .iter()
            .map(|p| coord.submit(Payload::Features(p.clone())).unwrap())
            .collect();
        for (c, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().class, Some(c));
        }
    }

    #[test]
    fn image_payload_without_wcfe_errors_cleanly() {
        let (coord, _) = proto_and_coordinator();
        let r = coord.call(Payload::Image(vec![0.0; 3072])).unwrap();
        assert!(r.error.is_some());
    }

    #[test]
    fn drop_joins_executor() {
        let (coord, _) = proto_and_coordinator();
        drop(coord); // must not hang
    }

    #[test]
    fn native_artifacts_spec_reports_missing_dir() {
        let opts = CoordinatorOptions {
            backend: BackendSpec::NativeArtifacts {
                artifacts: std::path::PathBuf::from("/definitely/not/artifacts"),
                config: "tiny".into(),
            },
            model: String::new(),
            tau: 0.5,
            min_segments: 1,
            search_mode: SearchMode::default(),
            mode_policy: ModePolicy::Auto,
            wcfe: WcfeSpec::default(),
            queue_depth: 8,
            threads: 1,
            snapshot_path: None,
            snapshot_every: 0,
            restore_path: None,
            wal_path: None,
            wal_fsync_every: 1,
        };
        assert!(Coordinator::start(opts).is_err());
    }

    #[test]
    fn submit_with_routes_many_replies_through_one_channel() {
        // the pipelined serving path: N requests share one reply channel
        // with caller-assigned ids; every reply comes back tagged with its
        // id and kind
        use crate::coordinator::ReplyKind;
        let (coord, protos) = proto_and_coordinator();
        let (tx, rx) = mpsc::sync_channel::<Response>(64);
        for (c, p) in protos.iter().enumerate() {
            coord
                .submit_with(1000 + c as u64, Payload::Learn(p.clone(), c), tx.clone())
                .unwrap();
        }
        for (c, p) in protos.iter().enumerate() {
            coord
                .submit_with(2000 + c as u64, Payload::Features(p.clone()), tx.clone())
                .unwrap();
        }
        coord.submit_with(3000, Payload::Stats, tx.clone()).unwrap();
        let mut got = std::collections::HashMap::new();
        for _ in 0..(2 * protos.len() + 1) {
            let r = rx.recv().unwrap();
            got.insert(r.id, r);
        }
        for c in 0..protos.len() {
            let learn = &got[&(1000 + c as u64)];
            assert_eq!(learn.kind, ReplyKind::Learn);
            assert!(learn.error.is_none(), "{:?}", learn.error);
            assert_eq!(learn.class, Some(c));
            let infer = &got[&(2000 + c as u64)];
            assert_eq!(infer.kind, ReplyKind::Classify);
            assert_eq!(infer.class, Some(c));
        }
        let stats = &got[&3000];
        assert_eq!(stats.kind, ReplyKind::Stats);
        assert_eq!(stats.stats.unwrap().learns, protos.len() as u64);
    }

    #[test]
    fn restore_refuses_a_checkpoint_from_another_model() {
        // same geometry, different registry identity: model A's knowledge
        // must never silently serve as model B's
        let path = snap_dir("model_identity").join("k.clok");
        let _ = std::fs::remove_file(&path);
        let cfg = HdConfig::synthetic("t", 8, 8, 32, 32, 8, 4);
        let mut opts_a = CoordinatorOptions::software(cfg.clone());
        opts_a.model = "alpha".into();
        let coord_a = Coordinator::start(opts_a).unwrap();
        let mut rng = Rng::new(404);
        let x: Vec<f32> = (0..cfg.features()).map(|_| rng.normal_f32() * 40.0).collect();
        coord_a.call(Payload::Learn(x, 0)).unwrap();
        let r = coord_a.call(Payload::Snapshot(Some(path.clone()))).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);

        let mut opts_b = CoordinatorOptions::software(cfg.clone());
        opts_b.model = "beta".into();
        let coord_b = Coordinator::start(opts_b).unwrap();
        let r = coord_b.call(Payload::Restore(path.clone())).unwrap();
        let msg = r.error.expect("cross-model restore must be refused");
        assert!(msg.contains("alpha") && msg.contains("beta"), "{msg}");

        // the same checkpoint restores fine into a model named alpha —
        // and into an unnamed (registry-free) coordinator, which keeps
        // pre-registry checkpoints and workflows working
        let mut opts_a2 = CoordinatorOptions::software(cfg.clone());
        opts_a2.model = "alpha".into();
        let coord_a2 = Coordinator::start(opts_a2).unwrap();
        assert!(coord_a2.call(Payload::Restore(path.clone())).unwrap().error.is_none());
        let coord_free = Coordinator::start(CoordinatorOptions::software(cfg)).unwrap();
        assert!(coord_free.call(Payload::Restore(path)).unwrap().error.is_none());
    }

    #[test]
    fn burst_learns_group_without_changing_results() {
        // fire every Learn without waiting: they pile up in the queue, so
        // the executor's greedy batcher hands them to handle_learn_run as
        // grouped runs (one backend encode per run) — results must be
        // indistinguishable from sequential learning
        let (coord, protos) = proto_and_coordinator();
        let mut rxs = Vec::new();
        for _ in 0..4 {
            for (c, p) in protos.iter().enumerate() {
                rxs.push(coord.submit(Payload::Learn(p.clone(), c)).unwrap());
            }
        }
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
            assert!(!r.early_exit);
        }
        for (c, p) in protos.iter().enumerate() {
            let r = coord.call(Payload::Features(p.clone())).unwrap();
            assert_eq!(r.class, Some(c));
        }
    }

    #[test]
    fn bad_learn_in_a_burst_errors_alone_without_poisoning_the_run() {
        // a grouped Learn run containing malformed requests: the bad ones
        // get individual error replies, the valid neighbors still bundle
        let (coord, protos) = proto_and_coordinator();
        let mut rxs = Vec::new();
        for _ in 0..3 {
            for (c, p) in protos.iter().enumerate() {
                rxs.push((false, coord.submit(Payload::Learn(p.clone(), c)).unwrap()));
            }
            // class out of range + wrong feature length, mid-burst
            rxs.push((true, coord.submit(Payload::Learn(protos[0].clone(), 99)).unwrap()));
            rxs.push((true, coord.submit(Payload::Learn(vec![0.0; 3], 0)).unwrap()));
        }
        for (expect_err, rx) in rxs {
            let r = rx.recv().unwrap();
            assert_eq!(r.error.is_some(), expect_err, "{:?}", r.error);
        }
        for (c, p) in protos.iter().enumerate() {
            let r = coord.call(Payload::Features(p.clone())).unwrap();
            assert_eq!(r.class, Some(c), "valid learns must have landed");
        }
    }

    #[test]
    fn explicit_thread_budget_serves_identically() {
        // --threads N end-to-end: a 4-thread executor must classify exactly
        // like the default one (every sharded kernel is bit-exact)
        let cfg = HdConfig::synthetic("t", 8, 8, 32, 32, 8, 4);
        let mut opts = CoordinatorOptions::software(cfg.clone());
        opts.threads = 4;
        let coord = Coordinator::start(opts).unwrap();
        let (base, protos) = proto_and_coordinator();
        for (c, p) in protos.iter().enumerate() {
            for _ in 0..3 {
                coord.call(Payload::Learn(p.clone(), c)).unwrap();
                base.call(Payload::Learn(p.clone(), c)).unwrap();
            }
        }
        for (c, p) in protos.iter().enumerate() {
            let threaded = coord.call(Payload::Features(p.clone())).unwrap();
            let serial = base.call(Payload::Features(p.clone())).unwrap();
            assert_eq!(threaded.class, Some(c));
            assert_eq!(threaded.class, serial.class);
            assert_eq!(threaded.segments_used, serial.segments_used);
        }
    }

    fn snap_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("clo_hdnn_coord_snap_{name}"));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn snapshot_restore_round_trips_through_channels() {
        let path = snap_dir("rt").join("k.clok");
        let _ = std::fs::remove_file(&path);
        let (coord, protos) = proto_and_coordinator();
        for (c, p) in protos.iter().enumerate() {
            for _ in 0..3 {
                coord.call(Payload::Learn(p.clone(), c)).unwrap();
            }
        }
        let r = coord.call(Payload::Snapshot(Some(path.clone()))).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.detail.as_deref(), Some(path.display().to_string().as_str()));
        assert!(path.exists());

        // a FRESH coordinator restored over the channel serves identically
        let (fresh, _) = proto_and_coordinator();
        let r = fresh.call(Payload::Restore(path.clone())).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        for (c, p) in protos.iter().enumerate() {
            for mode in [SearchMode::L1Int8, SearchMode::HammingPacked] {
                let orig = coord.call(Payload::FeaturesWithMode(p.clone(), mode)).unwrap();
                let rest = fresh.call(Payload::FeaturesWithMode(p.clone(), mode)).unwrap();
                assert_eq!(orig.class, Some(c));
                assert_eq!(orig.class, rest.class, "mode {mode:?} class {c}");
                assert_eq!(orig.segments_used, rest.segments_used);
                assert_eq!(orig.early_exit, rest.early_exit);
            }
        }
        // stats reflect the restored knowledge
        let s = fresh.call(Payload::Stats).unwrap().stats.unwrap();
        assert_eq!(s.learns, 12);
        assert_eq!(s.trained_classes, 4);
    }

    #[test]
    fn restore_path_option_warm_starts_the_executor() {
        let path = snap_dir("warm").join("k.clok");
        let _ = std::fs::remove_file(&path);
        let (coord, protos) = proto_and_coordinator();
        for (c, p) in protos.iter().enumerate() {
            coord.call(Payload::Learn(p.clone(), c)).unwrap();
        }
        coord.call(Payload::Snapshot(Some(path.clone()))).unwrap();
        drop(coord); // the original process is gone

        let cfg = HdConfig::synthetic("t", 8, 8, 32, 32, 8, 4);
        let mut opts = CoordinatorOptions::software(cfg);
        opts.restore_path = Some(path);
        let coord = Coordinator::start(opts).unwrap();
        for (c, p) in protos.iter().enumerate() {
            let r = coord.call(Payload::Features(p.clone())).unwrap();
            assert_eq!(r.class, Some(c), "restored knowledge must classify");
        }
    }

    #[test]
    fn restore_refuses_geometry_mismatch() {
        let path = snap_dir("geom").join("k.clok");
        let _ = std::fs::remove_file(&path);
        let (coord, _) = proto_and_coordinator();
        coord.call(Payload::Snapshot(Some(path.clone()))).unwrap();
        // 10-class config vs the checkpoint's 4-class geometry
        let cfg = HdConfig::synthetic("t", 8, 8, 32, 32, 8, 10);
        let coord10 = Coordinator::start(CoordinatorOptions::software(cfg.clone())).unwrap();
        let r = coord10.call(Payload::Restore(path.clone())).unwrap();
        assert!(r.error.is_some(), "geometry mismatch must be refused");
        // and a warm start over the same mismatch fails to boot
        let mut opts = CoordinatorOptions::software(cfg);
        opts.restore_path = Some(path);
        assert!(Coordinator::start(opts).is_err());
    }

    #[test]
    fn snapshot_without_target_errors_cleanly() {
        let (coord, _) = proto_and_coordinator();
        let r = coord.call(Payload::Snapshot(None)).unwrap();
        assert!(r.error.is_some());
        assert!(r.error.unwrap().contains("no path"));
    }

    #[test]
    fn auto_snapshot_every_n_learns() {
        let path = snap_dir("auto").join("k.clok");
        let _ = std::fs::remove_file(&path);
        let cfg = HdConfig::synthetic("t", 8, 8, 32, 32, 8, 4);
        let mut opts = CoordinatorOptions::software(cfg.clone());
        opts.snapshot_path = Some(path.clone());
        opts.snapshot_every = 4;
        let coord = Coordinator::start(opts).unwrap();
        let mut rng = Rng::new(77);
        let x: Vec<f32> = (0..cfg.features()).map(|_| rng.normal_f32() * 40.0).collect();
        for _ in 0..3 {
            coord.call(Payload::Learn(x.clone(), 0)).unwrap();
        }
        assert!(!path.exists(), "cadence is 4: no snapshot after 3 learns");
        coord.call(Payload::Learn(x.clone(), 0)).unwrap();
        // the 4th learn triggered the auto-snapshot on the executor thread
        // before it pulled the next request, so a follow-up call syncs us
        let s = coord.call(Payload::Stats).unwrap().stats.unwrap();
        assert_eq!(s.snapshots, 1);
        assert!(path.exists());
        let snap = crate::hdc::knowledge::load(&path).unwrap();
        assert_eq!(snap.total_learns(), 4);
        // shutdown flush: 2 more learns then drop -> final snapshot carries 6
        coord.call(Payload::Learn(x.clone(), 1)).unwrap();
        coord.call(Payload::Learn(x.clone(), 1)).unwrap();
        drop(coord);
        let snap = crate::hdc::knowledge::load(&path).unwrap();
        assert_eq!(snap.total_learns(), 6);
    }

    #[test]
    fn wal_recovery_is_bit_identical_to_the_acknowledged_prefix() {
        // crash simulation: no snapshot path, so dropping the coordinator
        // flushes nothing — the WAL is the only durability. The recovered
        // store must byte-match a store that learned the same stream live.
        let dir = snap_dir("wal_recover");
        let wal = dir.join("w.clog");
        let _ = std::fs::remove_file(&wal);
        let cfg = HdConfig::synthetic("t", 8, 8, 32, 32, 8, 4);
        let mut opts = CoordinatorOptions::software(cfg.clone());
        opts.wal_path = Some(wal.clone());
        let coord = Coordinator::start(opts).unwrap();
        let (reference, protos) = proto_and_coordinator();
        for (c, p) in protos.iter().enumerate() {
            for _ in 0..3 {
                assert!(coord.call(Payload::Learn(p.clone(), c)).unwrap().error.is_none());
                reference.call(Payload::Learn(p.clone(), c)).unwrap();
            }
        }
        let s = coord.call(Payload::Stats).unwrap().stats.unwrap();
        assert_eq!(s.learns, 12);
        assert_eq!(s.learn_seq, 12, "stats must stamp the log's seq");
        drop(coord);

        let mut opts = CoordinatorOptions::software(cfg.clone());
        opts.wal_path = Some(wal.clone());
        let recovered = Coordinator::start(opts).unwrap();
        let s = recovered.call(Payload::Stats).unwrap().stats.unwrap();
        assert_eq!(s.learns, 12, "every logged learn must replay");
        // bit-identity: snapshots of the recovered and reference stores
        // are byte-equal files
        let (pa, pb) = (dir.join("rec.clok"), dir.join("ref.clok"));
        recovered.call(Payload::Snapshot(Some(pa.clone()))).unwrap();
        reference.call(Payload::Snapshot(Some(pb.clone()))).unwrap();
        assert_eq!(
            std::fs::read(&pa).unwrap(),
            std::fs::read(&pb).unwrap(),
            "recovered store must be bit-identical to the live-learned one"
        );
        for (c, p) in protos.iter().enumerate() {
            assert_eq!(recovered.call(Payload::Features(p.clone())).unwrap().class, Some(c));
        }
    }

    #[test]
    fn wal_recovery_composes_with_a_snapshot_restore() {
        // snapshot at learn 4 (rotates the log), more learns, "crash",
        // restart restoring the snapshot: replay covers only the suffix
        let dir = snap_dir("wal_compose");
        let (wal, snap) = (dir.join("w.clog"), dir.join("k.clok"));
        let _ = std::fs::remove_file(&wal);
        let _ = std::fs::remove_file(&snap);
        let cfg = HdConfig::synthetic("t", 8, 8, 32, 32, 8, 4);
        let mut opts = CoordinatorOptions::software(cfg.clone());
        opts.wal_path = Some(wal.clone());
        opts.snapshot_path = Some(snap.clone());
        let coord = Coordinator::start(opts).unwrap();
        let (reference, protos) = proto_and_coordinator();
        for (c, p) in protos.iter().enumerate() {
            coord.call(Payload::Learn(p.clone(), c)).unwrap();
            reference.call(Payload::Learn(p.clone(), c)).unwrap();
        }
        coord.call(Payload::Snapshot(None)).unwrap();
        // the snapshot rotated the segment: a tail from before its fold
        // point now directs the caller to re-bootstrap
        let r = coord.call(Payload::WalTail { after: 0 }).unwrap();
        assert!(r.error.unwrap().contains("snapshot-fetch"));
        for (c, p) in protos.iter().enumerate() {
            coord.call(Payload::Learn(p.clone(), c)).unwrap();
            reference.call(Payload::Learn(p.clone(), c)).unwrap();
        }
        drop(coord);

        let mut opts = CoordinatorOptions::software(cfg);
        opts.wal_path = Some(wal);
        opts.restore_path = Some(snap);
        let recovered = Coordinator::start(opts).unwrap();
        let s = recovered.call(Payload::Stats).unwrap().stats.unwrap();
        assert_eq!(s.learns, 8);
        assert_eq!(s.learn_seq, 8);
        let (pa, pb) = (dir.join("rec.clok"), dir.join("ref.clok"));
        recovered.call(Payload::Snapshot(Some(pa.clone()))).unwrap();
        reference.call(Payload::Snapshot(Some(pb.clone()))).unwrap();
        assert_eq!(std::fs::read(&pa).unwrap(), std::fs::read(&pb).unwrap());
    }

    #[test]
    fn wal_tail_and_snapshot_fetch_through_channels() {
        let dir = snap_dir("wal_tail");
        let wal = dir.join("w.clog");
        let _ = std::fs::remove_file(&wal);
        let cfg = HdConfig::synthetic("t", 8, 8, 32, 32, 8, 4);
        let mut opts = CoordinatorOptions::software(cfg.clone());
        opts.wal_path = Some(wal);
        let coord = Coordinator::start(opts).unwrap();
        let (_, protos) = proto_and_coordinator();
        for (c, p) in protos.iter().enumerate() {
            coord.call(Payload::Learn(p.clone(), c)).unwrap();
        }
        // tail from 0: every record, in seq order, with the sample intact
        let r = coord.call(Payload::WalTail { after: 0 }).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.kind, crate::coordinator::ReplyKind::WalTail);
        let records = r.records.unwrap();
        assert_eq!(records.len(), 4);
        for (i, rec) in records.iter().enumerate() {
            assert_eq!(rec.seq, i as u64 + 1);
            assert_eq!(rec.class, i as u32);
            assert_eq!(rec.features, protos[i]);
        }
        assert_eq!(r.stats.unwrap().learn_seq, 4);
        // tail from the tip: empty, not an error (the follower's idle poll)
        let r = coord.call(Payload::WalTail { after: 4 }).unwrap();
        assert!(r.error.is_none());
        assert!(r.records.unwrap().is_empty());
        // snapshot-fetch: the image parses and matches the live store
        let r = coord.call(Payload::SnapshotFetch).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.kind, crate::coordinator::ReplyKind::SnapshotImage);
        let (store, model) =
            crate::hdc::knowledge::from_bytes_named(&r.image.unwrap()).unwrap();
        assert_eq!(model, "");
        assert_eq!(store.total_learns(), 4);
        // a fresh coordinator bootstrapped from the image serves identically
        let fresh = Coordinator::start(CoordinatorOptions::software(cfg)).unwrap();
        let img = coord.call(Payload::SnapshotFetch).unwrap().image.unwrap();
        assert!(fresh.call(Payload::RestoreImage(img)).unwrap().error.is_none());
        for (c, p) in protos.iter().enumerate() {
            assert_eq!(fresh.call(Payload::Features(p.clone())).unwrap().class, Some(c));
        }
        // without a WAL, tailing errors cleanly
        let r = fresh.call(Payload::WalTail { after: 0 }).unwrap();
        assert!(r.error.unwrap().contains("--wal"));
    }

    #[test]
    fn auto_snapshot_failure_keeps_serving_and_the_wal_consistent() {
        // an impossible snapshot target: every cadence hit fails, serving
        // and the learn log keep going (the warn-rate-limit path runs too)
        let dir = snap_dir("wal_failsnap");
        let wal = dir.join("w.clog");
        let _ = std::fs::remove_file(&wal);
        let cfg = HdConfig::synthetic("t", 8, 8, 32, 32, 8, 4);
        let block = dir.join("block");
        std::fs::write(&block, b"not a directory").unwrap();
        let mut opts = CoordinatorOptions::software(cfg.clone());
        opts.wal_path = Some(wal);
        // the snapshot parent is a regular file: create_dir_all fails
        opts.snapshot_path = Some(block.join("k.clok"));
        opts.snapshot_every = 2;
        let coord = Coordinator::start(opts).unwrap();
        let mut rng = Rng::new(99);
        let x: Vec<f32> = (0..cfg.features()).map(|_| rng.normal_f32() * 40.0).collect();
        for _ in 0..6 {
            assert!(coord.call(Payload::Learn(x.clone(), 0)).unwrap().error.is_none());
        }
        let s = coord.call(Payload::Stats).unwrap().stats.unwrap();
        assert_eq!(s.learns, 6);
        assert_eq!(s.learn_seq, 6);
        assert_eq!(s.snapshots, 0, "every auto-snapshot failed");
    }

    /// A WCFE-equipped coordinator over a 16x16x1 image geometry whose
    /// pixel count equals the HD feature count (256), so bypass and normal
    /// are both well-formed — the scenario-matrix shape. `scale_x` is tuned
    /// down so both [0,1] pixels and the FE's small GAP+FC outputs spread
    /// across the int8 range instead of rounding to {0, 1}.
    fn image_coordinator(policy: ModePolicy) -> (Coordinator, HdConfig) {
        let mut cfg = HdConfig::synthetic("img", 16, 16, 32, 32, 8, 4);
        cfg.scale_x = 0.02;
        let mut opts = CoordinatorOptions::software(cfg.clone());
        opts.mode_policy = policy;
        opts.wcfe = WcfeSpec::Seeded {
            image_hw: 16,
            image_c: 1,
            channels: vec![4, 8],
            clusters: 4,
            seed: 11,
        };
        (Coordinator::start(opts).unwrap(), cfg)
    }

    /// Class-distinct images: each class gets its own brightness band plus
    /// per-pixel texture, so both raw pixels and GAP-pooled FE features
    /// separate the classes.
    fn image_protos(cfg: &HdConfig, n_classes: usize) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(55);
        (0..n_classes)
            .map(|c| {
                let base = 0.1 + 0.25 * c as f32;
                (0..cfg.features())
                    .map(|_| (base + rng.normal_f32() * 0.08).clamp(0.0, 1.0))
                    .collect()
            })
            .collect()
    }

    #[test]
    fn seeded_wcfe_serves_images_and_counts_modes() {
        let (coord, cfg) = image_coordinator(ModePolicy::Auto);
        let protos = image_protos(&cfg, 4);
        // image learns route through the FE under Auto (the satellite fix)
        for (c, p) in protos.iter().enumerate() {
            for _ in 0..3 {
                let r = coord.call(Payload::LearnImage(p.clone(), c)).unwrap();
                assert!(r.error.is_none(), "{:?}", r.error);
            }
        }
        // image queries run normal mode and recover the learned class
        for (c, p) in protos.iter().enumerate() {
            let r = coord.call(Payload::Image(p.clone())).unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
            assert_eq!(r.class, Some(c));
            assert!(r.used_wcfe && !r.escalated);
            assert!(r.energy_j > 0.0, "normal-mode queries carry energy");
        }
        // feature-space queries on extracted features bypass
        let s = coord.call(Payload::Stats).unwrap().stats.unwrap();
        assert_eq!(s.normal, 4);
        assert_eq!(s.bypass, 0);
        assert_eq!(s.escalations, 0);
        assert_eq!(s.policy, ModePolicy::Auto.code());
        assert_eq!(s.learns, 12);
    }

    #[test]
    fn burst_image_queries_batch_identically_to_singletons() {
        let (coord, cfg) = image_coordinator(ModePolicy::Auto);
        let protos = image_protos(&cfg, 4);
        for (c, p) in protos.iter().enumerate() {
            coord.call(Payload::LearnImage(p.clone(), c)).unwrap();
        }
        // singleton answers first
        let singles: Vec<_> = protos
            .iter()
            .map(|p| coord.call(Payload::Image(p.clone())).unwrap())
            .collect();
        // now fire the same queries as a burst (plus one malformed image):
        // the executor groups them into handle_image_run
        let mut rxs = Vec::new();
        for _ in 0..3 {
            for p in &protos {
                rxs.push((false, coord.submit(Payload::Image(p.clone())).unwrap()));
            }
            rxs.push((true, coord.submit(Payload::Image(vec![0.5; 7])).unwrap()));
        }
        for (k, (expect_err, rx)) in rxs.into_iter().enumerate() {
            let r = rx.recv().unwrap();
            assert_eq!(r.error.is_some(), expect_err, "req {k}: {:?}", r.error);
            if !expect_err {
                let single = &singles[k % (protos.len() + 1)];
                assert_eq!(r.class, single.class);
                assert_eq!(r.segments_used, single.segments_used);
                assert!(r.used_wcfe);
            }
        }
    }

    #[test]
    fn confidence_policy_matches_forced_modes_bitwise() {
        // the escalation-correctness property at the coordinator level:
        // per request, Confidence == ForceNormal when it escalates and
        // == ForceBypass when it does not. All three coordinators learn an
        // IDENTICAL feature-space stream (Payload::Learn bypasses routing),
        // so their stores are bit-identical and any prediction divergence
        // could only come from the routing layer under test.
        let (bypass, cfg) = image_coordinator(ModePolicy::ForceBypass);
        let (normal, _) = image_coordinator(ModePolicy::ForceNormal);
        let protos = image_protos(&cfg, 4);
        let mut rng = Rng::new(77);
        let stream: Vec<Vec<f32>> = (0..24)
            .map(|i| {
                let noise = if i % 2 == 0 { 0.02 } else { 0.45 };
                protos[i % 4]
                    .iter()
                    .map(|&v| (v + rng.normal_f32() * noise).clamp(0.0, 1.0))
                    .collect()
            })
            .collect();
        for (c, p) in protos.iter().enumerate() {
            for coord in [&bypass, &normal] {
                for _ in 0..3 {
                    assert!(coord
                        .call(Payload::Learn(p.clone(), c))
                        .unwrap()
                        .error
                        .is_none());
                }
            }
        }
        // low and high thresholds pull the escalation rate toward the two
        // extremes; equality with the matching reference must hold at any
        // rate in between
        for margin in [25.0f32, 100_000.0] {
            let (conf, _) = image_coordinator(ModePolicy::Confidence { margin });
            for (c, p) in protos.iter().enumerate() {
                for _ in 0..3 {
                    conf.call(Payload::Learn(p.clone(), c)).unwrap();
                }
            }
            let mut fired = 0u64;
            for q in &stream {
                let rc = conf.call(Payload::Image(q.clone())).unwrap();
                assert!(rc.error.is_none(), "{:?}", rc.error);
                let reference = if rc.escalated {
                    assert!(rc.used_wcfe);
                    fired += 1;
                    normal.call(Payload::Image(q.clone())).unwrap()
                } else {
                    assert!(!rc.used_wcfe);
                    bypass.call(Payload::Image(q.clone())).unwrap()
                };
                assert_eq!(rc.class, reference.class);
                assert_eq!(rc.segments_used, reference.segments_used);
                assert_eq!(rc.early_exit, reference.early_exit);
            }
            let s = conf.call(Payload::Stats).unwrap().stats.unwrap();
            assert_eq!(s.escalations, fired);
            assert_eq!(s.normal, fired);
            assert_eq!(s.bypass, stream.len() as u64 - fired);
            assert_eq!(s.policy, 3);
            assert_eq!(s.policy_margin, margin);
        }
    }

    #[test]
    fn per_request_packed_mode_classifies_through_channels() {
        let (coord, protos) = proto_and_coordinator();
        for (c, p) in protos.iter().enumerate() {
            for _ in 0..3 {
                coord.call(Payload::Learn(p.clone(), c)).unwrap();
            }
        }
        // same requests, one per mode: both kernels must recover the class
        for (c, p) in protos.iter().enumerate() {
            let scalar = coord
                .call(Payload::FeaturesWithMode(p.clone(), SearchMode::L1Int8))
                .unwrap();
            let packed = coord
                .call(Payload::FeaturesWithMode(p.clone(), SearchMode::HammingPacked))
                .unwrap();
            assert!(scalar.error.is_none() && packed.error.is_none());
            assert_eq!(scalar.class, Some(c));
            assert_eq!(packed.class, Some(c));
        }
        // the override is per-request: a plain Features call still works
        let r = coord.call(Payload::Features(protos[0].clone())).unwrap();
        assert_eq!(r.class, Some(0));
    }
}
