//! Dual-mode router (Fig.4): decides per request whether the WCFE runs
//! (normal mode) or is bypassed. The chip's rule is payload-driven — raw
//! images need feature extraction, pre-extracted features go straight to
//! the HD module through the CDC FIFO — with an optional force override
//! (the host can pin a mode for a deployment) and a confidence-escalating
//! policy that serves images bypass-first and upgrades to the WCFE only
//! when the progressive search terminates with a thin top-2 margin.

use crate::coordinator::request::Payload;
use crate::sim::Mode;
use crate::Result;
use anyhow::bail;

/// How the router picks between WCFE (normal) and bypass mode.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum ModePolicy {
    /// payload-driven (images -> normal, features -> bypass)
    #[default]
    Auto,
    /// always bypass the WCFE
    ForceBypass,
    /// always run the WCFE
    ForceNormal,
    /// bypass-first with escalation: an image query is first classified on
    /// its raw pixels; when the progressive search's terminal top-2 margin
    /// (Hamming or L1, in distance units) lands **below** `margin`, the
    /// executor re-runs the same request through the WCFE — so easy
    /// queries pay bypass cost and only ambiguous ones pay for feature
    /// extraction. Escalated predictions are bit-identical to
    /// [`ModePolicy::ForceNormal`] on the same request, non-escalated ones
    /// to [`ModePolicy::ForceBypass`].
    Confidence {
        /// escalation threshold on the terminal top-2 margin
        margin: f32,
    },
}

impl ModePolicy {
    /// Parse a CLI/manifest spelling: `auto`, `bypass`, `normal`, or
    /// `confidence:<margin>` (e.g. `confidence:96`).
    pub fn parse(s: &str) -> Result<ModePolicy> {
        match s {
            "auto" => Ok(ModePolicy::Auto),
            "bypass" | "force-bypass" => Ok(ModePolicy::ForceBypass),
            "normal" | "force-normal" => Ok(ModePolicy::ForceNormal),
            other => match other.strip_prefix("confidence:") {
                Some(m) => {
                    let margin: f32 = m.parse().map_err(|_| {
                        anyhow::anyhow!("confidence policy margin '{m}' is not a number")
                    })?;
                    if !margin.is_finite() || margin < 0.0 {
                        bail!("confidence policy margin must be finite and >= 0 (got {margin})");
                    }
                    Ok(ModePolicy::Confidence { margin })
                }
                None => bail!(
                    "unknown mode policy '{other}' (auto|bypass|normal|confidence:<margin>)"
                ),
            },
        }
    }

    /// Stable wire code for the policy (what stats replies carry).
    pub fn code(&self) -> u8 {
        match self {
            ModePolicy::Auto => 0,
            ModePolicy::ForceBypass => 1,
            ModePolicy::ForceNormal => 2,
            ModePolicy::Confidence { .. } => 3,
        }
    }

    /// Inverse of [`ModePolicy::code`] (stats decode); unknown codes fall
    /// back to `Auto` so old clients stay readable against newer servers.
    pub fn from_code(code: u8, margin: f32) -> ModePolicy {
        match code {
            1 => ModePolicy::ForceBypass,
            2 => ModePolicy::ForceNormal,
            3 => ModePolicy::Confidence { margin },
            _ => ModePolicy::Auto,
        }
    }

    /// The escalation threshold (0 for non-confidence policies).
    pub fn margin(&self) -> f32 {
        match self {
            ModePolicy::Confidence { margin } => *margin,
            _ => 0.0,
        }
    }

    /// Human spelling, `ModePolicy::parse`-compatible.
    pub fn spelling(&self) -> String {
        match self {
            ModePolicy::Auto => "auto".into(),
            ModePolicy::ForceBypass => "bypass".into(),
            ModePolicy::ForceNormal => "normal".into(),
            ModePolicy::Confidence { margin } => format!("confidence:{margin}"),
        }
    }
}

/// The per-request dual-mode router.
#[derive(Clone, Copy, Debug, Default)]
pub struct Router {
    /// the active routing policy
    pub policy: ModePolicy,
}

impl Router {
    /// Pick the **initial** execution mode for one payload. The Confidence
    /// policy starts image queries in bypass; the escalation re-run is the
    /// executor's decision (it needs the classify margin).
    pub fn route(&self, payload: &Payload) -> Mode {
        match (self.policy, payload) {
            (ModePolicy::ForceBypass, _) => Mode::Bypass,
            (ModePolicy::ForceNormal, _) => Mode::Normal,
            // learns from raw pixels always need the FE (outside a forced
            // bypass): there is no second chance to re-extract once the
            // sample is bundled into the store
            (_, Payload::LearnImage(..)) => Mode::Normal,
            (ModePolicy::Auto, Payload::Image(_) | Payload::ImageWithMode(..)) => Mode::Normal,
            (ModePolicy::Auto, _) => Mode::Bypass,
            // bypass-first: the executor escalates after seeing the margin
            (ModePolicy::Confidence { .. }, _) => Mode::Bypass,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_routes_by_payload() {
        let r = Router::default();
        assert_eq!(r.route(&Payload::Features(vec![0.0])), Mode::Bypass);
        assert_eq!(r.route(&Payload::Image(vec![0.0])), Mode::Normal);
        // feature-space learns bypass; raw-image learns need the FE
        assert_eq!(r.route(&Payload::Learn(vec![0.0], 1)), Mode::Bypass);
        assert_eq!(r.route(&Payload::LearnImage(vec![0.0], 1)), Mode::Normal);
        // the search-mode override does not affect WCFE routing
        let p = Payload::FeaturesWithMode(vec![0.0], crate::hdc::SearchMode::HammingPacked);
        assert_eq!(r.route(&p), Mode::Bypass);
        let p = Payload::ImageWithMode(vec![0.0], crate::hdc::SearchMode::HammingPacked);
        assert_eq!(r.route(&p), Mode::Normal);
    }

    #[test]
    fn overrides_win() {
        let rb = Router { policy: ModePolicy::ForceBypass };
        assert_eq!(rb.route(&Payload::Image(vec![0.0])), Mode::Bypass);
        assert_eq!(rb.route(&Payload::LearnImage(vec![0.0], 1)), Mode::Bypass);
        let rn = Router { policy: ModePolicy::ForceNormal };
        assert_eq!(rn.route(&Payload::Features(vec![0.0])), Mode::Normal);
    }

    #[test]
    fn confidence_starts_in_bypass_except_learns() {
        let r = Router { policy: ModePolicy::Confidence { margin: 50.0 } };
        assert_eq!(r.route(&Payload::Image(vec![0.0])), Mode::Bypass);
        assert_eq!(r.route(&Payload::Features(vec![0.0])), Mode::Bypass);
        assert_eq!(r.route(&Payload::LearnImage(vec![0.0], 1)), Mode::Normal);
        assert_eq!(r.route(&Payload::Learn(vec![0.0], 1)), Mode::Bypass);
    }

    #[test]
    fn policy_parse_and_codes() {
        assert_eq!(ModePolicy::parse("auto").unwrap(), ModePolicy::Auto);
        assert_eq!(ModePolicy::parse("bypass").unwrap(), ModePolicy::ForceBypass);
        assert_eq!(ModePolicy::parse("normal").unwrap(), ModePolicy::ForceNormal);
        assert_eq!(
            ModePolicy::parse("confidence:96.5").unwrap(),
            ModePolicy::Confidence { margin: 96.5 }
        );
        assert!(ModePolicy::parse("confidence:x").is_err());
        assert!(ModePolicy::parse("confidence:-1").is_err());
        assert!(ModePolicy::parse("dual").is_err());
        for p in [
            ModePolicy::Auto,
            ModePolicy::ForceBypass,
            ModePolicy::ForceNormal,
            ModePolicy::Confidence { margin: 12.0 },
        ] {
            assert_eq!(ModePolicy::from_code(p.code(), p.margin()), p);
            assert_eq!(ModePolicy::parse(&p.spelling()).unwrap(), p);
        }
        assert_eq!(ModePolicy::from_code(200, 1.0), ModePolicy::Auto);
    }
}
