//! Dual-mode router (Fig.4): decides per request whether the WCFE runs
//! (normal mode) or is bypassed. The chip's rule is payload-driven — raw
//! images need feature extraction, pre-extracted features go straight to
//! the HD module through the CDC FIFO — with an optional force override
//! (the host can pin a mode for a deployment).

use crate::coordinator::request::Payload;
use crate::sim::Mode;

/// How the router picks between WCFE (normal) and bypass mode.
#[derive(Clone, Copy, Debug, Default)]
pub enum ModePolicy {
    /// payload-driven (images -> normal, features -> bypass)
    #[default]
    Auto,
    /// always bypass the WCFE
    ForceBypass,
    /// always run the WCFE
    ForceNormal,
}

/// The per-request dual-mode router.
#[derive(Clone, Copy, Debug, Default)]
pub struct Router {
    /// the active routing policy
    pub policy: ModePolicy,
}

impl Router {
    /// Pick the execution mode for one payload.
    pub fn route(&self, payload: &Payload) -> Mode {
        match (self.policy, payload) {
            (ModePolicy::ForceBypass, _) => Mode::Bypass,
            (ModePolicy::ForceNormal, _) => Mode::Normal,
            (ModePolicy::Auto, Payload::Image(_)) => Mode::Normal,
            (ModePolicy::Auto, _) => Mode::Bypass,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_routes_by_payload() {
        let r = Router::default();
        assert_eq!(r.route(&Payload::Features(vec![0.0])), Mode::Bypass);
        assert_eq!(r.route(&Payload::Image(vec![0.0])), Mode::Normal);
        assert_eq!(r.route(&Payload::Learn(vec![0.0], 1)), Mode::Bypass);
        // the search-mode override does not affect WCFE routing
        let p = Payload::FeaturesWithMode(vec![0.0], crate::hdc::SearchMode::HammingPacked);
        assert_eq!(r.route(&p), Mode::Bypass);
    }

    #[test]
    fn overrides_win() {
        let rb = Router { policy: ModePolicy::ForceBypass };
        assert_eq!(rb.route(&Payload::Image(vec![0.0])), Mode::Bypass);
        let rn = Router { policy: ModePolicy::ForceNormal };
        assert_eq!(rn.route(&Payload::Features(vec![0.0])), Mode::Normal);
    }
}
