//! Request/response types crossing the client <-> executor channel.

use crate::hdc::SearchMode;
use std::path::PathBuf;
use std::time::Instant;

/// What the client submits.
#[derive(Clone, Debug)]
pub enum Payload {
    /// pre-extracted features (bypass mode candidates)
    Features(Vec<f32>),
    /// pre-extracted features with an explicit per-request search mode
    /// (overrides the coordinator's default INT8-L1 / packed-Hamming choice
    /// for this one classification)
    FeaturesWithMode(Vec<f32>, SearchMode),
    /// raw image (h*w*c in [0,1]) — requires the WCFE (normal mode)
    Image(Vec<f32>),
    /// labeled sample: learn instead of classify
    Learn(Vec<f32>, usize),
    /// persist the learned knowledge (class hypervectors) to the given
    /// path, or to the coordinator's configured default when `None`;
    /// atomic write-rename, see `crate::hdc::knowledge`
    Snapshot(Option<PathBuf>),
    /// replace the live knowledge store with the checkpoint at the path
    /// (geometry must match the serving backend's config)
    Restore(PathBuf),
    /// report knowledge/serving counters (no classification)
    Stats,
}

/// One queued unit of work: a payload plus the reply channel the executor
/// answers on.
#[derive(Debug)]
pub struct Request {
    /// caller-assigned id, echoed on the [`Response`] (the serving layer
    /// passes the client's wire id through here)
    pub id: u64,
    /// the operation
    pub payload: Payload,
    /// submission timestamp (queueing-latency accounting)
    pub submitted: Instant,
    /// reply channel (one-shot)
    pub reply: std::sync::mpsc::SyncSender<Response>,
}

/// Which operation a [`Response`] answers. The serving layer translates
/// executor replies back onto the wire with this tag instead of tracking
/// per-request state — which is what lets replies complete out of order on
/// a pipelined connection.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReplyKind {
    /// a classification ([`Payload::Features`]/[`Payload::FeaturesWithMode`]/
    /// [`Payload::Image`])
    #[default]
    Classify,
    /// a [`Payload::Learn`] acknowledgement
    Learn,
    /// a [`Payload::Snapshot`] acknowledgement (`detail` carries the path)
    Snapshot,
    /// a [`Payload::Restore`] acknowledgement (`detail` carries the path)
    Restore,
    /// a [`Payload::Stats`] reply (`stats` carries the counters)
    Stats,
}

/// Knowledge counters a [`Payload::Stats`] request reports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CoordStats {
    /// total bundled (positive) learns in the live store
    pub learns: u64,
    /// classes with at least one bundled sample
    pub trained_classes: usize,
    /// snapshots taken this process (explicit + auto)
    pub snapshots: u64,
}

/// What the executor returns.
#[derive(Clone, Debug)]
pub struct Response {
    /// echo of [`Request::id`]
    pub id: u64,
    /// which operation this answers (see [`ReplyKind`])
    pub kind: ReplyKind,
    /// predicted class (classification) or the class learned (learn ack)
    pub class: Option<usize>,
    /// progressive-search segments evaluated
    pub segments_used: usize,
    /// whether the search exited before the last segment
    pub early_exit: bool,
    /// whether the WCFE ran (normal mode)
    pub used_wcfe: bool,
    /// executor-side latency in seconds
    pub latency_s: f64,
    /// free-form success detail (e.g. the snapshot path written)
    pub detail: Option<String>,
    /// knowledge counters (set for [`Payload::Stats`] replies)
    pub stats: Option<CoordStats>,
    /// failure detail; when set, every other result field is meaningless
    pub error: Option<String>,
}

impl Response {
    /// A non-classification success (snapshot/restore/stats replies).
    pub fn ok(id: u64) -> Response {
        Response {
            id,
            kind: ReplyKind::Classify,
            class: None,
            segments_used: 0,
            early_exit: false,
            used_wcfe: false,
            latency_s: 0.0,
            detail: None,
            stats: None,
            error: None,
        }
    }

    /// A failure reply carrying the error detail.
    pub fn error(id: u64, msg: String) -> Response {
        Response {
            error: Some(msg),
            ..Response::ok(id)
        }
    }
}
