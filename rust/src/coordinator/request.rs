//! Request/response types crossing the client <-> executor channel.

use crate::hdc::wal::WalRecord;
use crate::hdc::SearchMode;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// What the client submits.
#[derive(Clone, Debug)]
pub enum Payload {
    /// pre-extracted features (bypass mode candidates)
    Features(Vec<f32>),
    /// pre-extracted features with an explicit per-request search mode
    /// (overrides the coordinator's default INT8-L1 / packed-Hamming choice
    /// for this one classification)
    FeaturesWithMode(Vec<f32>, SearchMode),
    /// raw image (h*w*c in [0,1]) — the WCFE extracts features in normal
    /// mode; under a bypass policy the pixels feed the encoder directly
    Image(Vec<f32>),
    /// raw image with an explicit per-request search mode (the image
    /// analogue of [`Payload::FeaturesWithMode`])
    ImageWithMode(Vec<f32>, SearchMode),
    /// labeled sample: learn instead of classify
    Learn(Vec<f32>, usize),
    /// labeled raw image: the WCFE extracts features first (unless the
    /// policy forces bypass), then the sample is learned — what lets
    /// normal-mode models learn from images, not just features
    LearnImage(Vec<f32>, usize),
    /// persist the learned knowledge (class hypervectors) to the given
    /// path, or to the coordinator's configured default when `None`;
    /// atomic write-rename, see `crate::hdc::knowledge`
    Snapshot(Option<PathBuf>),
    /// replace the live knowledge store with the checkpoint at the path
    /// (geometry must match the serving backend's config)
    Restore(PathBuf),
    /// replace the live knowledge store with an in-memory CLOK image
    /// (a follower bootstrapping from `OP_SNAPSHOT_FETCH` bytes); same
    /// identity/geometry checks as [`Payload::Restore`]
    RestoreImage(Vec<u8>),
    /// report knowledge/serving counters (no classification)
    Stats,
    /// the learn-log records with sequence number greater than `after`
    /// (replication tailing; requires the coordinator to run with a WAL)
    WalTail {
        /// the highest sequence number the caller has already applied
        after: u64,
    },
    /// serialize the live knowledge store to an in-memory CLOK image
    /// (replication bootstrap; works with or without a WAL)
    SnapshotFetch,
    /// follower promotion: bump the model's epoch (generation counter) to
    /// `max(current, min_epoch) + 1` and — when the coordinator keeps a
    /// WAL — seal the inherited log position by rotating to a fresh
    /// segment at `base_seq = total_learns()` under the new epoch. After
    /// this the model is a primary of the new generation: stale
    /// lower-epoch peers are fenced by every wal-tail/stats reply carrying
    /// the epoch. `min_epoch` is the promotion floor: a follower that
    /// tailed its primary at epoch E passes E here, so the new generation
    /// outranks the failed primary even when the follower's own lineage
    /// started at 0 (pass 0 when no source epoch is known).
    Promote {
        /// highest source epoch the caller observed (0 = none known)
        min_epoch: u64,
    },
}

/// Where an executor delivers a completed [`Response`]. The sink variant
/// never blocks: the serving reactor's single event-loop thread must stay
/// responsive, so completions are handed to a routing sink (which tags
/// them with a connection token and wakes the loop) instead of a bounded
/// channel an executor could stall on.
pub trait ReplySink: Send + Sync {
    /// Deliver one completed response. Must not block.
    fn complete(&self, resp: Response);
}

/// The reply half of a [`Request`]: either a caller-owned channel (the
/// blocking `call`/`submit` paths) or a non-blocking [`ReplySink`] (the
/// serving reactor path).
#[derive(Clone)]
pub enum ReplyTo {
    /// a caller-owned channel; the caller sizes it so the executor's send
    /// cannot block (see `Coordinator::submit_with`)
    Channel(mpsc::SyncSender<Response>),
    /// a non-blocking routing sink (see `Coordinator::try_submit_sink`)
    Sink(Arc<dyn ReplySink>),
}

impl ReplyTo {
    /// Deliver the response; returns `false` when the receiving side is
    /// gone (the executor ignores that — a dead client is not an error).
    pub fn send(&self, resp: Response) -> bool {
        match self {
            ReplyTo::Channel(tx) => tx.send(resp).is_ok(),
            ReplyTo::Sink(sink) => {
                sink.complete(resp);
                true
            }
        }
    }
}

impl std::fmt::Debug for ReplyTo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReplyTo::Channel(_) => f.write_str("ReplyTo::Channel"),
            ReplyTo::Sink(_) => f.write_str("ReplyTo::Sink"),
        }
    }
}

/// One queued unit of work: a payload plus the reply route the executor
/// answers on.
#[derive(Debug)]
pub struct Request {
    /// caller-assigned id, echoed on the [`Response`] (the serving layer
    /// passes the client's wire id through here)
    pub id: u64,
    /// the operation
    pub payload: Payload,
    /// submission timestamp (queueing-latency accounting)
    pub submitted: Instant,
    /// reply route (one response per request)
    pub reply: ReplyTo,
}

/// Which operation a [`Response`] answers. The serving layer translates
/// executor replies back onto the wire with this tag instead of tracking
/// per-request state — which is what lets replies complete out of order on
/// a pipelined connection.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReplyKind {
    /// a classification ([`Payload::Features`]/[`Payload::FeaturesWithMode`]/
    /// [`Payload::Image`])
    #[default]
    Classify,
    /// a [`Payload::Learn`] acknowledgement
    Learn,
    /// a [`Payload::Snapshot`] acknowledgement (`detail` carries the path)
    Snapshot,
    /// a [`Payload::Restore`]/[`Payload::RestoreImage`] acknowledgement
    /// (`detail` carries the path or image provenance)
    Restore,
    /// a [`Payload::Stats`] reply (`stats` carries the counters)
    Stats,
    /// a [`Payload::WalTail`] reply (`records` carries the suffix, `stats`
    /// the counters — `stats.learn_seq` is the log's current last sequence)
    WalTail,
    /// a [`Payload::SnapshotFetch`] reply (`image` carries the CLOK bytes)
    SnapshotImage,
    /// a [`Payload::Promote`] acknowledgement (`stats.epoch` is the new
    /// generation, `stats.learn_seq` the sealed base)
    Promote,
}

/// Knowledge counters a [`Payload::Stats`] request reports.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CoordStats {
    /// total bundled (positive) learns in the live store
    pub learns: u64,
    /// classes with at least one bundled sample
    pub trained_classes: usize,
    /// snapshots taken this process (explicit + auto)
    pub snapshots: u64,
    /// monotonic learn sequence number: the WAL's last acknowledged
    /// sequence when the coordinator logs learns, else the store's total
    /// learn count — what followers compare against the primary to detect
    /// stale reads
    pub learn_seq: u64,
    /// classifications answered without the WCFE (bypass mode)
    pub bypass: u64,
    /// classifications answered through the WCFE (normal mode)
    pub normal: u64,
    /// bypass-first classifications the Confidence policy re-ran through
    /// the WCFE because the top-2 margin fell below its threshold
    pub escalations: u64,
    /// active mode policy (`ModePolicy::code`: 0 auto, 1 force-bypass,
    /// 2 force-normal, 3 confidence)
    pub policy: u8,
    /// the Confidence policy's escalation margin (0 for other policies)
    pub policy_margin: f32,
    /// promotion generation: 0 on an original primary's lineage, +1 per
    /// [`Payload::Promote`]. Stamped into WAL segment headers and carried
    /// by stats/wal-tail wire replies so stale old primaries are fenced.
    pub epoch: u64,
}

/// What the executor returns.
#[derive(Clone, Debug)]
pub struct Response {
    /// echo of [`Request::id`]
    pub id: u64,
    /// which operation this answers (see [`ReplyKind`])
    pub kind: ReplyKind,
    /// predicted class (classification) or the class learned (learn ack)
    pub class: Option<usize>,
    /// progressive-search segments evaluated
    pub segments_used: usize,
    /// whether the search exited before the last segment
    pub early_exit: bool,
    /// whether the WCFE ran (normal mode)
    pub used_wcfe: bool,
    /// whether the Confidence policy re-ran this request through the WCFE
    /// after a thin bypass margin (implies `used_wcfe`)
    pub escalated: bool,
    /// modeled energy for this query in joules (chip datapath op counts x
    /// the calibrated per-op energies at the serving voltage; 0 when the
    /// executor has no energy accounting attached)
    pub energy_j: f64,
    /// executor-side latency in seconds
    pub latency_s: f64,
    /// free-form success detail (e.g. the snapshot path written)
    pub detail: Option<String>,
    /// knowledge counters (set for [`Payload::Stats`] and
    /// [`Payload::WalTail`] replies)
    pub stats: Option<CoordStats>,
    /// learn-log suffix (set for [`Payload::WalTail`] replies)
    pub records: Option<Vec<WalRecord>>,
    /// the log segment's fold point (set for [`Payload::WalTail`]
    /// replies): learns at or before this sequence live only in the
    /// snapshot the segment was rotated against
    pub wal_base: Option<u64>,
    /// serialized CLOK image (set for [`Payload::SnapshotFetch`] replies)
    pub image: Option<Vec<u8>>,
    /// failure detail; when set, every other result field is meaningless
    pub error: Option<String>,
}

impl Response {
    /// A non-classification success (snapshot/restore/stats replies).
    pub fn ok(id: u64) -> Response {
        Response {
            id,
            kind: ReplyKind::Classify,
            class: None,
            segments_used: 0,
            early_exit: false,
            used_wcfe: false,
            escalated: false,
            energy_j: 0.0,
            latency_s: 0.0,
            detail: None,
            stats: None,
            records: None,
            wal_base: None,
            image: None,
            error: None,
        }
    }

    /// A failure reply carrying the error detail.
    pub fn error(id: u64, msg: String) -> Response {
        Response {
            error: Some(msg),
            ..Response::ok(id)
        }
    }
}
