//! Request/response types crossing the client <-> executor channel.

use crate::hdc::SearchMode;
use std::time::Instant;

/// What the client submits.
#[derive(Clone, Debug)]
pub enum Payload {
    /// pre-extracted features (bypass mode candidates)
    Features(Vec<f32>),
    /// pre-extracted features with an explicit per-request search mode
    /// (overrides the coordinator's default INT8-L1 / packed-Hamming choice
    /// for this one classification)
    FeaturesWithMode(Vec<f32>, SearchMode),
    /// raw image (h*w*c in [0,1]) — requires the WCFE (normal mode)
    Image(Vec<f32>),
    /// labeled sample: learn instead of classify
    Learn(Vec<f32>, usize),
}

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub payload: Payload,
    pub submitted: Instant,
    /// reply channel (one-shot)
    pub reply: std::sync::mpsc::SyncSender<Response>,
}

/// What the executor returns.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub class: Option<usize>,
    pub segments_used: usize,
    pub early_exit: bool,
    /// whether the WCFE ran (normal mode)
    pub used_wcfe: bool,
    pub latency_s: f64,
    pub error: Option<String>,
}

impl Response {
    pub fn error(id: u64, msg: String) -> Response {
        Response {
            id,
            class: None,
            segments_used: 0,
            early_exit: false,
            used_wcfe: false,
            latency_s: 0.0,
            error: Some(msg),
        }
    }
}
