//! The task-incremental experiment loop behind Fig.9: train task t, then
//! evaluate on every seen task's test samples; produces the accuracy
//! matrix + forgetting report per learner.

use crate::cl::learners::ContinualLearner;
use crate::cl::metrics::AccuracyMatrix;
use crate::data::{Dataset, TaskStream};
use crate::Result;

/// One learner's full run over a task stream.
#[derive(Clone, Debug)]
pub struct ClRun {
    pub learner: String,
    pub matrix: AccuracyMatrix,
    pub final_accuracy: f64,
    pub mean_forgetting: f64,
    pub mean_segments: Option<f64>,
}

pub struct ClHarness<'a> {
    pub train: &'a Dataset,
    pub test: &'a Dataset,
    pub stream: &'a TaskStream,
    /// cap evaluation samples per task (speed knob for big test sets)
    pub eval_cap: usize,
}

impl<'a> ClHarness<'a> {
    pub fn new(train: &'a Dataset, test: &'a Dataset, stream: &'a TaskStream) -> ClHarness<'a> {
        ClHarness { train, test, stream, eval_cap: usize::MAX }
    }

    /// Accuracy of `learner` on the test samples of one task's classes.
    /// A task with zero test samples is unmeasurable and reports NaN — the
    /// [`AccuracyMatrix`] convention for "not measured" — instead of a
    /// phantom 0.0 that would drag down `final_average` and inflate
    /// `mean_forgetting`.
    fn eval_task(&self, learner: &mut dyn ContinualLearner, task_id: usize) -> Result<f64> {
        let classes = &self.stream.tasks[task_id].classes;
        let idx = self.test.indices_of_classes(classes);
        let take = idx.len().min(self.eval_cap);
        if take == 0 {
            return Ok(f64::NAN);
        }
        let mut correct = 0usize;
        for &i in idx.iter().take(take) {
            if learner.predict(self.test.sample(i))? == self.test.label(i) {
                correct += 1;
            }
        }
        Ok(correct as f64 / take as f64)
    }

    /// Run the full stream for one learner.
    pub fn run(&self, learner: &mut dyn ContinualLearner) -> Result<ClRun> {
        let n = self.stream.len();
        let mut matrix = AccuracyMatrix::new(n);
        for t in 0..n {
            learner.learn_task(self.train, &self.stream.tasks[t])?;
            for tau in 0..=t {
                let acc = self.eval_task(learner, tau)?;
                matrix.set(t, tau, acc);
            }
        }
        Ok(ClRun {
            learner: learner.name(),
            final_accuracy: matrix.final_average(),
            mean_forgetting: matrix.mean_forgetting(),
            mean_segments: learner.mean_segments(),
            matrix,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{LinearSgd, NearestMean};
    use crate::cl::learners::{HdLearner, NcmLearner, SgdLearner};
    use crate::config::HdConfig;
    use crate::hdc::encoder::SoftwareEncoder;
    use crate::hdc::{HdClassifier, ProgressiveSearch, Trainer};
    use crate::util::Rng;

    fn blob_pair(classes: usize, feat: usize, seed: u64) -> (Dataset, Dataset) {
        // shared positive base couples tasks (see linear_sgd tests): HDC is
        // insensitive to it, gradient fine-tuning forgets through it
        let mut rng = Rng::new(seed);
        let protos: Vec<Vec<f32>> = (0..classes)
            .map(|_| (0..feat).map(|_| rng.normal_f32() * 30.0).collect())
            .collect();
        let mk = |per: usize, rng: &mut Rng| {
            let mut x = Vec::new();
            let mut y = Vec::new();
            for c in 0..classes {
                for _ in 0..per {
                    x.extend(
                        protos[c]
                            .iter()
                            .map(|&v| 60.0 + v + rng.normal_f32() * 4.0),
                    );
                    y.push(c as u16);
                }
            }
            Dataset::from_parts(x, y, feat, classes).unwrap()
        };
        (mk(12, &mut rng), mk(6, &mut rng))
    }

    #[test]
    fn hdc_beats_naive_sgd_on_forgetting() {
        let (train, test) = blob_pair(8, 64, 61);
        let stream = TaskStream::class_incremental(&train, 4, 2);
        let h = ClHarness::new(&train, &test, &stream);

        let cfg = HdConfig::synthetic("t", 8, 8, 32, 32, 8, 8);
        let mut hd = HdLearner::new(
            HdClassifier::new(
                Box::new(SoftwareEncoder::random(cfg, 62)),
                ProgressiveSearch { tau: 0.4, min_segments: 1, ..Default::default() },
            ),
            Trainer { retrain_epochs: 1 },
        );
        let mut sgd = SgdLearner(LinearSgd::new(64, 8, 0.1, 6, 0, 63));

        let hd_run = h.run(&mut hd).unwrap();
        let sgd_run = h.run(&mut sgd).unwrap();

        assert!(hd_run.final_accuracy > 0.85, "hdc {}", hd_run.final_accuracy);
        assert!(
            hd_run.mean_forgetting < 0.1,
            "hdc forgetting {}",
            hd_run.mean_forgetting
        );
        assert!(
            sgd_run.mean_forgetting > hd_run.mean_forgetting + 0.15,
            "sgd {} vs hdc {}",
            sgd_run.mean_forgetting,
            hd_run.mean_forgetting
        );
        assert!(hd_run.mean_segments.is_some());
    }

    #[test]
    fn ncm_also_immune_to_forgetting() {
        let (train, test) = blob_pair(6, 32, 71);
        let stream = TaskStream::class_incremental(&train, 3, 3);
        let h = ClHarness::new(&train, &test, &stream);
        let mut ncm = NcmLearner(NearestMean::new(32, 6));
        let run = h.run(&mut ncm).unwrap();
        assert!(run.final_accuracy > 0.9);
        assert!(run.mean_forgetting < 0.05);
    }

    #[test]
    fn zero_sample_task_reports_nan_not_zero() {
        // train covers 4 classes, but the test set is restricted to task
        // 0's classes — task 1 then has ZERO test samples
        let (train, test_full) = blob_pair(4, 32, 91);
        let stream = TaskStream::class_incremental(&train, 2, 2);
        let task0 = stream.tasks[0].classes.clone();
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..test_full.n {
            if task0.contains(&test_full.label(i)) {
                x.extend_from_slice(test_full.sample(i));
                y.push(test_full.label(i) as u16);
            }
        }
        let test = Dataset::from_parts(x, y, 32, 4).unwrap();
        let h = ClHarness::new(&train, &test, &stream);
        let mut ncm = NcmLearner(NearestMean::new(32, 4));
        let run = h.run(&mut ncm).unwrap();
        // task 1 is unmeasurable: NaN in the matrix, skipped in aggregates
        assert!(run.matrix.get(1, 1).is_nan());
        assert!(!run.final_accuracy.is_nan());
        assert!(
            run.final_accuracy > 0.8,
            "empty task dragged the average down: {}",
            run.final_accuracy
        );
        assert!(run.mean_forgetting < 0.1, "{}", run.mean_forgetting);
    }

    #[test]
    fn eval_cap_limits_work() {
        let (train, test) = blob_pair(4, 32, 81);
        let stream = TaskStream::class_incremental(&train, 2, 4);
        let mut h = ClHarness::new(&train, &test, &stream);
        h.eval_cap = 3;
        let mut ncm = NcmLearner(NearestMean::new(32, 4));
        let run = h.run(&mut ncm).unwrap();
        assert!(run.final_accuracy >= 0.0 && run.final_accuracy <= 1.0);
    }
}
