//! Continual-learning metrics: the accuracy matrix A[t][tau] (accuracy on
//! task tau's classes after training task t), average accuracy, and
//! backward-transfer / forgetting.

/// Row-major accuracy matrix over `n_tasks` training checkpoints.
#[derive(Clone, Debug)]
pub struct AccuracyMatrix {
    pub n_tasks: usize,
    /// a[t * n_tasks + tau] = accuracy on task tau after training task t
    /// (NaN for tau > t: not yet seen)
    pub a: Vec<f64>,
}

impl AccuracyMatrix {
    pub fn new(n_tasks: usize) -> AccuracyMatrix {
        AccuracyMatrix { n_tasks, a: vec![f64::NAN; n_tasks * n_tasks] }
    }

    pub fn set(&mut self, after_task: usize, on_task: usize, acc: f64) {
        self.a[after_task * self.n_tasks + on_task] = acc;
    }

    pub fn get(&self, after_task: usize, on_task: usize) -> f64 {
        self.a[after_task * self.n_tasks + on_task]
    }

    /// Mean accuracy over all seen tasks after the final task — the Fig.9
    /// end-of-stream number.
    pub fn final_average(&self) -> f64 {
        let t = self.n_tasks - 1;
        (0..self.n_tasks).map(|tau| self.get(t, tau)).sum::<f64>() / self.n_tasks as f64
    }

    /// Average accuracy on seen tasks after each checkpoint (learning curve).
    pub fn curve(&self) -> Vec<f64> {
        (0..self.n_tasks)
            .map(|t| (0..=t).map(|tau| self.get(t, tau)).sum::<f64>() / (t + 1) as f64)
            .collect()
    }

    /// Mean forgetting: max historical accuracy minus final accuracy, over
    /// tasks 0..n-1 (classic CL metric; ~0 for HDC, large for naive SGD).
    pub fn mean_forgetting(&self) -> f64 {
        if self.n_tasks < 2 {
            return 0.0;
        }
        let last = self.n_tasks - 1;
        let mut total = 0.0;
        for tau in 0..last {
            let peak = (tau..self.n_tasks)
                .map(|t| self.get(t, tau))
                .fold(f64::NEG_INFINITY, f64::max);
            total += (peak - self.get(last, tau)).max(0.0);
        }
        total / last as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> AccuracyMatrix {
        // 2 tasks: task0 acc 0.9 after t0, drops to 0.5 after t1; task1 0.8
        let mut m = AccuracyMatrix::new(2);
        m.set(0, 0, 0.9);
        m.set(1, 0, 0.5);
        m.set(1, 1, 0.8);
        m
    }

    #[test]
    fn final_average() {
        assert!((demo().final_average() - 0.65).abs() < 1e-12);
    }

    #[test]
    fn forgetting() {
        assert!((demo().mean_forgetting() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn curve_shape() {
        let c = demo().curve();
        assert_eq!(c.len(), 2);
        assert!((c[0] - 0.9).abs() < 1e-12);
        assert!((c[1] - 0.65).abs() < 1e-12);
    }

    #[test]
    fn no_forgetting_when_stable() {
        let mut m = AccuracyMatrix::new(2);
        m.set(0, 0, 0.9);
        m.set(1, 0, 0.92); // improved!
        m.set(1, 1, 0.8);
        assert_eq!(m.mean_forgetting(), 0.0);
    }
}
