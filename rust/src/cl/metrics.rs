//! Continual-learning metrics: the accuracy matrix A[t][tau] (accuracy on
//! task tau's classes after training task t), average accuracy, and
//! backward-transfer / forgetting.

/// Row-major accuracy matrix over `n_tasks` training checkpoints.
#[derive(Clone, Debug)]
pub struct AccuracyMatrix {
    pub n_tasks: usize,
    /// a[t * n_tasks + tau] = accuracy on task tau after training task t
    /// (NaN for tau > t: not yet seen)
    pub a: Vec<f64>,
}

impl AccuracyMatrix {
    pub fn new(n_tasks: usize) -> AccuracyMatrix {
        AccuracyMatrix { n_tasks, a: vec![f64::NAN; n_tasks * n_tasks] }
    }

    pub fn set(&mut self, after_task: usize, on_task: usize, acc: f64) {
        self.a[after_task * self.n_tasks + on_task] = acc;
    }

    pub fn get(&self, after_task: usize, on_task: usize) -> f64 {
        self.a[after_task * self.n_tasks + on_task]
    }

    /// Mean accuracy over all seen tasks after the final task — the Fig.9
    /// end-of-stream number. NaN entries (the matrix convention for "not
    /// measured", which also covers tasks with zero test samples) are
    /// skipped rather than poisoning the mean; an all-NaN row yields NaN.
    pub fn final_average(&self) -> f64 {
        let t = self.n_tasks - 1;
        nan_mean((0..self.n_tasks).map(|tau| self.get(t, tau)))
    }

    /// Average accuracy on seen tasks after each checkpoint (learning
    /// curve), NaN entries skipped per checkpoint.
    pub fn curve(&self) -> Vec<f64> {
        (0..self.n_tasks)
            .map(|t| nan_mean((0..=t).map(|tau| self.get(t, tau))))
            .collect()
    }

    /// Mean forgetting: max historical accuracy minus final accuracy, over
    /// tasks 0..n-1 (classic CL metric; ~0 for HDC, large for naive SGD).
    /// A task with no measured accuracy (all-NaN column — e.g. no test
    /// samples for its classes) is excluded from the mean instead of
    /// inflating it.
    pub fn mean_forgetting(&self) -> f64 {
        if self.n_tasks < 2 {
            return 0.0;
        }
        let last = self.n_tasks - 1;
        let mut total = 0.0;
        let mut counted = 0usize;
        for tau in 0..last {
            let final_acc = self.get(last, tau);
            let peak = (tau..self.n_tasks)
                .map(|t| self.get(t, tau))
                .filter(|a| !a.is_nan())
                .fold(f64::NEG_INFINITY, f64::max);
            if final_acc.is_nan() || peak == f64::NEG_INFINITY {
                continue;
            }
            total += (peak - final_acc).max(0.0);
            counted += 1;
        }
        if counted == 0 {
            return 0.0;
        }
        total / counted as f64
    }
}

/// Mean over the non-NaN values; NaN when nothing was measured.
fn nan_mean(vals: impl Iterator<Item = f64>) -> f64 {
    let (mut sum, mut n) = (0.0f64, 0usize);
    for v in vals {
        if !v.is_nan() {
            sum += v;
            n += 1;
        }
    }
    if n == 0 {
        return f64::NAN;
    }
    sum / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> AccuracyMatrix {
        // 2 tasks: task0 acc 0.9 after t0, drops to 0.5 after t1; task1 0.8
        let mut m = AccuracyMatrix::new(2);
        m.set(0, 0, 0.9);
        m.set(1, 0, 0.5);
        m.set(1, 1, 0.8);
        m
    }

    #[test]
    fn final_average() {
        assert!((demo().final_average() - 0.65).abs() < 1e-12);
    }

    #[test]
    fn forgetting() {
        assert!((demo().mean_forgetting() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn curve_shape() {
        let c = demo().curve();
        assert_eq!(c.len(), 2);
        assert!((c[0] - 0.9).abs() < 1e-12);
        assert!((c[1] - 0.65).abs() < 1e-12);
    }

    #[test]
    fn nan_tasks_do_not_poison_aggregates() {
        // 3 tasks; task 1 was never measurable (zero-sample task): its
        // column stays NaN through every checkpoint
        let mut m = AccuracyMatrix::new(3);
        m.set(0, 0, 0.9);
        m.set(1, 0, 0.8);
        m.set(2, 0, 0.7);
        m.set(2, 2, 0.6);
        // final row: [0.7, NaN, 0.6] -> mean over measured = 0.65
        assert!((m.final_average() - 0.65).abs() < 1e-12);
        // curve checkpoint 1 averages only task 0 (task 1 is NaN)
        let c = m.curve();
        assert!((c[1] - 0.8).abs() < 1e-12);
        // forgetting counts only task 0 (peak 0.9, final 0.7); the NaN
        // column is excluded instead of being treated as total forgetting
        assert!((m.mean_forgetting() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn all_nan_final_row_is_nan_not_zero() {
        let m = AccuracyMatrix::new(2);
        assert!(m.final_average().is_nan());
        assert!(m.curve().iter().all(|v| v.is_nan()));
        assert_eq!(m.mean_forgetting(), 0.0);
    }

    #[test]
    fn no_forgetting_when_stable() {
        let mut m = AccuracyMatrix::new(2);
        m.set(0, 0, 0.9);
        m.set(1, 0, 0.92); // improved!
        m.set(1, 1, 0.8);
        assert_eq!(m.mean_forgetting(), 0.0);
    }
}
