//! Continual-learning harness (Fig.1/Fig.9): task-incremental protocol,
//! accuracy matrix, forgetting metrics, over any [`ContinualLearner`].

pub mod harness;
pub mod learners;
pub mod metrics;

pub use harness::{ClHarness, ClRun};
pub use learners::ContinualLearner;
pub use metrics::AccuracyMatrix;
