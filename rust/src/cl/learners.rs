//! [`ContinualLearner`] — the interface the CL harness drives — and its
//! implementations: the HDC classifier (ours) and the baselines.

use crate::baselines::{LinearSgd, NearestMean};
use crate::data::{Dataset, Task};
use crate::hdc::{HdClassifier, Trainer};
use crate::Result;

pub trait ContinualLearner {
    fn name(&self) -> String;
    fn learn_task(&mut self, ds: &Dataset, task: &Task) -> Result<()>;
    fn predict(&mut self, x: &[f32]) -> Result<usize>;
    /// mean segments used per prediction, if the learner is progressive
    fn mean_segments(&self) -> Option<f64> {
        None
    }
}

/// The Clo-HDnn learner: gradient-free HDC with progressive search.
pub struct HdLearner {
    pub classifier: HdClassifier,
    pub trainer: Trainer,
    seg_used: u64,
    preds: u64,
}

impl HdLearner {
    pub fn new(classifier: HdClassifier, trainer: Trainer) -> HdLearner {
        HdLearner { classifier, trainer, seg_used: 0, preds: 0 }
    }
}

impl ContinualLearner for HdLearner {
    fn name(&self) -> String {
        format!("Clo-HDnn (tau={})", self.classifier.policy.tau)
    }

    fn learn_task(&mut self, ds: &Dataset, task: &Task) -> Result<()> {
        self.trainer.train_task(&mut self.classifier, ds, task)?;
        Ok(())
    }

    fn predict(&mut self, x: &[f32]) -> Result<usize> {
        let r = self.classifier.classify(x)?;
        self.seg_used += r.segments_used as u64;
        self.preds += 1;
        Ok(r.class)
    }

    fn mean_segments(&self) -> Option<f64> {
        (self.preds > 0).then(|| self.seg_used as f64 / self.preds as f64)
    }
}

/// FP32 gradient baseline (stand-in for [5]).
pub struct SgdLearner(pub LinearSgd);

impl ContinualLearner for SgdLearner {
    fn name(&self) -> String {
        if self.0.replay_budget > 0 {
            format!("FP32 SGD + replay({})", self.0.replay_budget)
        } else {
            "FP32 SGD (no replay)".into()
        }
    }

    fn learn_task(&mut self, ds: &Dataset, task: &Task) -> Result<()> {
        self.0.train_task(ds, task);
        Ok(())
    }

    fn predict(&mut self, x: &[f32]) -> Result<usize> {
        Ok(self.0.predict(x))
    }
}

/// Nearest-class-mean baseline.
pub struct NcmLearner(pub NearestMean);

impl ContinualLearner for NcmLearner {
    fn name(&self) -> String {
        "Nearest-class-mean".into()
    }

    fn learn_task(&mut self, ds: &Dataset, task: &Task) -> Result<()> {
        self.0.train_task(ds, task);
        Ok(())
    }

    fn predict(&mut self, x: &[f32]) -> Result<usize> {
        Ok(self.0.predict(x))
    }
}
