//! Nearest-class-mean baseline: the geometry floor every encoder/classifier
//! comparison is sanity-checked against (and itself a replay-free continual
//! learner, since class means are independent).

use crate::data::{Dataset, Task};

pub struct NearestMean {
    pub sums: Vec<f64>,
    pub counts: Vec<u64>,
    pub dim: usize,
    pub classes: usize,
}

impl NearestMean {
    pub fn new(dim: usize, classes: usize) -> NearestMean {
        NearestMean { sums: vec![0.0; dim * classes], counts: vec![0; classes], dim, classes }
    }

    pub fn learn(&mut self, x: &[f32], y: usize) {
        for (j, &v) in x.iter().enumerate() {
            self.sums[y * self.dim + j] += v as f64;
        }
        self.counts[y] += 1;
    }

    pub fn train_task(&mut self, ds: &Dataset, task: &Task) {
        for &i in &task.train_indices {
            self.learn(ds.sample(i), ds.label(i));
        }
    }

    pub fn predict(&self, x: &[f32]) -> usize {
        let mut best = 0usize;
        let mut bd = f64::INFINITY;
        for c in 0..self.classes {
            if self.counts[c] == 0 {
                continue;
            }
            let inv = 1.0 / self.counts[c] as f64;
            let mut d = 0.0f64;
            for (j, &v) in x.iter().enumerate() {
                let m = self.sums[c * self.dim + j] * inv;
                let diff = v as f64 - m;
                d += diff * diff;
            }
            if d < bd {
                bd = d;
                best = c;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn classifies_two_blobs() {
        let mut m = NearestMean::new(4, 2);
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let a: Vec<f32> = (0..4).map(|_| 1.0 + rng.normal_f32() * 0.1).collect();
            let b: Vec<f32> = (0..4).map(|_| -1.0 + rng.normal_f32() * 0.1).collect();
            m.learn(&a, 0);
            m.learn(&b, 1);
        }
        assert_eq!(m.predict(&[1.0, 1.0, 1.0, 1.0]), 0);
        assert_eq!(m.predict(&[-1.0, -1.0, -1.0, -1.0]), 1);
    }

    #[test]
    fn untrained_classes_never_predicted() {
        let mut m = NearestMean::new(2, 3);
        m.learn(&[1.0, 0.0], 0);
        assert_eq!(m.predict(&[100.0, 100.0]), 0);
    }
}
