//! FP32 softmax-regression learner trained by SGD — the gradient-based
//! float baseline (stand-in for [5], see DESIGN.md Substitutions). Under
//! the task-incremental protocol WITHOUT replay it exhibits the
//! catastrophic forgetting the paper's Fig.1 motivates (challenge C2);
//! `replay_budget > 0` enables a small episodic-replay buffer for the
//! stronger baseline variant.

use crate::data::{Dataset, Task};
use crate::util::Rng;

pub struct LinearSgd {
    pub w: Vec<f32>,
    /// per-class bias — trained jointly; under class-incremental fine-tuning
    /// the new classes' biases grow while unseen-in-batch classes' biases
    /// only ever receive downward gradient (task-recency bias), the textbook
    /// forgetting mechanism of challenge C2
    pub b: Vec<f32>,
    pub dim: usize,
    pub classes: usize,
    pub lr: f32,
    pub epochs: usize,
    /// replay-buffer capacity in samples (0 = pure SGD, forgets)
    pub replay_budget: usize,
    replay: Vec<(Vec<f32>, usize)>,
    rng: Rng,
    /// FP32 multiply-accumulate count (cost accounting vs gradient-free HDC)
    pub flops: u64,
}

impl LinearSgd {
    pub fn new(dim: usize, classes: usize, lr: f32, epochs: usize,
               replay_budget: usize, seed: u64) -> LinearSgd {
        LinearSgd {
            w: vec![0.0; dim * classes],
            b: vec![0.0; classes],
            dim,
            classes,
            lr,
            epochs,
            replay_budget,
            replay: Vec::new(),
            rng: Rng::new(seed),
            flops: 0,
        }
    }

    pub fn logits(&self, x: &[f32]) -> Vec<f32> {
        let mut out = self.b.clone();
        for (j, &v) in x.iter().enumerate() {
            if v == 0.0 {
                continue;
            }
            let row = &self.w[j * self.classes..(j + 1) * self.classes];
            for (o, &w) in out.iter_mut().zip(row) {
                *o += v * w;
            }
        }
        out
    }

    pub fn predict(&self, x: &[f32]) -> usize {
        let l = self.logits(x);
        l.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    fn sgd_step(&mut self, x: &[f32], y: usize) {
        let logits = self.logits(x);
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = logits.iter().map(|&l| (l - m).exp()).collect();
        let z: f32 = exps.iter().sum();
        for c in 0..self.classes {
            let err = exps[c] / z - f32::from(c == y);
            self.b[c] -= self.lr * err;
        }
        for (j, &v) in x.iter().enumerate() {
            let row = &mut self.w[j * self.classes..(j + 1) * self.classes];
            for (c, w) in row.iter_mut().enumerate() {
                let p = exps[c] / z;
                let g = (p - f32::from(c == y)) * v;
                *w -= self.lr * g;
            }
        }
        self.flops += (2 * self.dim * self.classes) as u64 * 2; // fwd + bwd
    }

    /// Train on one task's samples (+ replay buffer), SGD with shuffling.
    pub fn train_task(&mut self, ds: &Dataset, task: &Task) {
        // stash replay samples from this task
        if self.replay_budget > 0 {
            let per_task = self.replay_budget / (task.id + 1).max(1);
            for &i in task.train_indices.iter().take(per_task) {
                self.replay.push((ds.sample(i).to_vec(), ds.label(i)));
            }
            while self.replay.len() > self.replay_budget {
                let k = self.rng.below(self.replay.len());
                self.replay.swap_remove(k);
            }
        }
        for _ in 0..self.epochs {
            let mut order = task.train_indices.clone();
            self.rng.shuffle(&mut order);
            for &i in &order {
                let (x, y) = (ds.sample(i).to_vec(), ds.label(i));
                self.sgd_step(&x, y);
            }
            if !self.replay.is_empty() {
                let replay_snapshot: Vec<(Vec<f32>, usize)> = self.replay.clone();
                for (x, y) in replay_snapshot {
                    self.sgd_step(&x, y);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::TaskStream;

    fn blob_dataset(classes: usize, per_class: usize, feat: usize, seed: u64) -> Dataset {
        // Non-negative-ish data (like pixels / spectral features): a shared
        // positive base + class proto. The shared component is what couples
        // tasks — new-task gradients push old-class weights down along it,
        // producing the catastrophic forgetting of challenge C2.
        let mut rng = Rng::new(seed);
        let protos: Vec<Vec<f32>> = (0..classes)
            .map(|_| (0..feat).map(|_| rng.normal_f32()).collect())
            .collect();
        let mut x = Vec::new();
        let mut y = Vec::new();
        for c in 0..classes {
            for _ in 0..per_class {
                x.extend(
                    protos[c]
                        .iter()
                        .map(|&v| 1.0 + v + rng.normal_f32() * 0.15),
                );
                y.push(c as u16);
            }
        }
        Dataset::from_parts(x, y, feat, classes).unwrap()
    }

    fn acc(m: &LinearSgd, ds: &Dataset, classes: &[usize]) -> f64 {
        let idx = ds.indices_of_classes(classes);
        let ok = idx.iter().filter(|&&i| m.predict(ds.sample(i)) == ds.label(i)).count();
        ok as f64 / idx.len() as f64
    }

    #[test]
    fn learns_single_task_blobs() {
        let ds = blob_dataset(5, 20, 16, 1);
        let stream = TaskStream::class_incremental(&ds, 1, 1);
        let mut m = LinearSgd::new(16, 5, 0.1, 5, 0, 2);
        m.train_task(&ds, &stream.tasks[0]);
        assert!(acc(&m, &ds, &(0..5).collect::<Vec<_>>()) > 0.9);
        assert!(m.flops > 0);
    }

    #[test]
    fn forgets_without_replay_hdc_does_not() {
        // The paper's core CL story (Fig.1 C2 vs Fig.2): gradient training
        // overwrites earlier tasks; HDC's independent CHVs do not.
        let ds = blob_dataset(6, 25, 16, 3);
        let stream = TaskStream::class_incremental(&ds, 3, 5);
        let mut m = LinearSgd::new(16, 6, 0.1, 8, 0, 4);
        m.train_task(&ds, &stream.tasks[0]);
        let before = acc(&m, &ds, &stream.tasks[0].classes);
        m.train_task(&ds, &stream.tasks[1]);
        m.train_task(&ds, &stream.tasks[2]);
        let after = acc(&m, &ds, &stream.tasks[0].classes);
        assert!(before > 0.9, "task0 never learned: {before}");
        assert!(
            after < before - 0.3,
            "expected catastrophic forgetting: {before} -> {after}"
        );
    }

    #[test]
    fn replay_mitigates_forgetting() {
        let ds = blob_dataset(6, 25, 16, 3);
        let stream = TaskStream::class_incremental(&ds, 3, 5);
        let mut m = LinearSgd::new(16, 6, 0.1, 8, 60, 4);
        m.train_task(&ds, &stream.tasks[0]);
        let before = acc(&m, &ds, &stream.tasks[0].classes);
        m.train_task(&ds, &stream.tasks[1]);
        m.train_task(&ds, &stream.tasks[2]);
        let after = acc(&m, &ds, &stream.tasks[0].classes);
        assert!(after > before - 0.25, "replay failed: {before} -> {after}");
    }
}
