//! Baselines the paper compares against:
//! * encoder families for Fig.5 — RP [11], cyclic RP [4], ID-LEVEL [12];
//! * the FP32 gradient learner standing in for the float baseline [5] of
//!   Fig.9 (exhibits catastrophic forgetting without replay);
//! * nearest-class-mean (the geometry sanity floor).

pub mod encoders;
pub mod linear_sgd;
pub mod nearest_mean;

pub use encoders::{CrpEncoder, IdLevelEncoder, RpEncoder};
pub use linear_sgd::LinearSgd;
pub use nearest_mean::NearestMean;
