//! Baseline HD encoders (Fig.5 comparison): conventional random projection
//! (RP [11]), cyclic RP (cRP [4]), and ID-LEVEL [12]. All produce
//! INT-quantized QHVs comparable to the Kronecker encoder's, with the op
//! and memory footprints the Fig.5 table contrasts.

use crate::config::HdConfig;
use crate::hdc::quantize;
use crate::util::Rng;

/// Common interface for the encoder-family bench.
pub trait BaselineEncoder {
    fn name(&self) -> &'static str;
    fn encode(&self, x: &[f32]) -> Vec<f32>;
    /// add-equivalent ops per encode
    fn ops(&self) -> u64;
    /// parameter storage in bits
    fn mem_bits(&self) -> u64;
}

/// Dense +-1 random projection: QHV = sign-ish(R @ x), R is (D, F).
pub struct RpEncoder {
    pub cfg: HdConfig,
    r: Vec<f32>,
}

impl RpEncoder {
    pub fn new(cfg: HdConfig, seed: u64) -> RpEncoder {
        let mut rng = Rng::new(seed);
        let r = (0..cfg.dim() * cfg.features()).map(|_| rng.sign()).collect();
        RpEncoder { cfg, r }
    }
}

impl BaselineEncoder for RpEncoder {
    fn name(&self) -> &'static str {
        "RP"
    }

    fn encode(&self, x: &[f32]) -> Vec<f32> {
        let f = self.cfg.features();
        (0..self.cfg.dim())
            .map(|i| {
                let row = &self.r[i * f..(i + 1) * f];
                let acc: f32 = row
                    .iter()
                    .zip(x)
                    .map(|(&r, &v)| if r >= 0.0 { v } else { -v })
                    .sum();
                quantize::quantize(acc, self.cfg.qbits, self.cfg.scale_q)
            })
            .collect()
    }

    fn ops(&self) -> u64 {
        (self.cfg.dim() * self.cfg.features()) as u64
    }

    fn mem_bits(&self) -> u64 {
        (self.cfg.dim() * self.cfg.features()) as u64
    }
}

/// Cyclic RP [4]: one +-1 seed row per D/F block, rotated per output row —
/// same compute as RP, storage reduced to the seed rows.
pub struct CrpEncoder {
    pub cfg: HdConfig,
    seeds: Vec<Vec<f32>>,
}

impl CrpEncoder {
    pub fn new(cfg: HdConfig, seed: u64) -> CrpEncoder {
        let mut rng = Rng::new(seed);
        let f = cfg.features();
        let blocks = cfg.dim().div_ceil(f);
        let seeds = (0..blocks)
            .map(|_| (0..f).map(|_| rng.sign()).collect())
            .collect();
        CrpEncoder { cfg, seeds }
    }
}

impl BaselineEncoder for CrpEncoder {
    fn name(&self) -> &'static str {
        "cRP"
    }

    fn encode(&self, x: &[f32]) -> Vec<f32> {
        let f = self.cfg.features();
        (0..self.cfg.dim())
            .map(|i| {
                let seed = &self.seeds[i / f];
                let rot = i % f;
                let acc: f32 = (0..f)
                    .map(|j| {
                        let r = seed[(j + rot) % f];
                        if r >= 0.0 { x[j] } else { -x[j] }
                    })
                    .sum();
                quantize::quantize(acc, self.cfg.qbits, self.cfg.scale_q)
            })
            .collect()
    }

    fn ops(&self) -> u64 {
        (self.cfg.dim() * self.cfg.features()) as u64
    }

    fn mem_bits(&self) -> u64 {
        (self.seeds.len() * self.cfg.features()) as u64
    }
}

/// ID-LEVEL [12]: per-feature binary item HV bound to a quantized-level HV,
/// bundled over features: QHV_i = sum_j item[j][i] * level(x_j)[i].
pub struct IdLevelEncoder {
    pub cfg: HdConfig,
    pub levels: usize,
    items: Vec<f32>,
    level_hvs: Vec<f32>,
}

impl IdLevelEncoder {
    pub fn new(cfg: HdConfig, levels: usize, seed: u64) -> IdLevelEncoder {
        let mut rng = Rng::new(seed);
        let d = cfg.dim();
        let items = (0..cfg.features() * d).map(|_| rng.sign()).collect();
        // correlated level HVs: start random, flip a random 1/levels chunk
        // per step (the standard thermometer construction)
        let mut level_hvs = Vec::with_capacity(levels * d);
        let mut cur: Vec<f32> = (0..d).map(|_| rng.sign()).collect();
        level_hvs.extend_from_slice(&cur);
        let flips = d / levels.max(1);
        for _ in 1..levels {
            for _ in 0..flips {
                let k = rng.below(d);
                cur[k] = -cur[k];
            }
            level_hvs.extend_from_slice(&cur);
        }
        IdLevelEncoder { cfg, levels, items, level_hvs }
    }

    fn level_of(&self, v: f32) -> usize {
        // features are INT8 valued (-127..127) -> level bucket
        let norm = (v + 127.0) / 254.0;
        ((norm * (self.levels - 1) as f32).round() as usize).min(self.levels - 1)
    }
}

impl BaselineEncoder for IdLevelEncoder {
    fn name(&self) -> &'static str {
        "ID-LEVEL"
    }

    fn encode(&self, x: &[f32]) -> Vec<f32> {
        let d = self.cfg.dim();
        let mut acc = vec![0.0f32; d];
        for (j, &v) in x.iter().enumerate() {
            let item = &self.items[j * d..(j + 1) * d];
            let lvl = self.level_of(v);
            let level = &self.level_hvs[lvl * d..(lvl + 1) * d];
            for i in 0..d {
                acc[i] += item[i] * level[i];
            }
        }
        acc.iter()
            .map(|&a| quantize::quantize(a, self.cfg.qbits, 1.0))
            .collect()
    }

    fn ops(&self) -> u64 {
        (self.cfg.dim() * self.cfg.features()) as u64
    }

    fn mem_bits(&self) -> u64 {
        (self.cfg.dim() * (self.cfg.features() + self.levels)) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdc::encoder::{kron_cost, SoftwareEncoder};
    use crate::hdc::HdBackend;
    use crate::util::prop::gen;

    fn cfg() -> HdConfig {
        HdConfig::synthetic("t", 8, 8, 32, 32, 8, 10)
    }

    #[test]
    fn all_encoders_produce_quantized_d_dim_output() {
        let mut rng = crate::util::Rng::new(1);
        let x = gen::int8_vec(&mut rng, 64);
        let encoders: Vec<Box<dyn BaselineEncoder>> = vec![
            Box::new(RpEncoder::new(cfg(), 2)),
            Box::new(CrpEncoder::new(cfg(), 3)),
            Box::new(IdLevelEncoder::new(cfg(), 16, 4)),
        ];
        for e in &encoders {
            let q = e.encode(&x);
            assert_eq!(q.len(), 1024, "{}", e.name());
            assert!(q.iter().all(|v| v.abs() <= 127.0 && v.fract() == 0.0));
        }
    }

    #[test]
    fn similar_inputs_give_similar_codes() {
        // locality: the encodings must preserve neighborhood structure, or
        // the classifier comparison across encoders is meaningless
        let mut rng = crate::util::Rng::new(5);
        let x: Vec<f32> = gen::int8_vec(&mut rng, 64);
        let mut near = x.clone();
        for v in near.iter_mut().take(4) {
            *v += 1.0;
        }
        let far: Vec<f32> = gen::int8_vec(&mut rng, 64);
        for e in [
            Box::new(RpEncoder::new(cfg(), 2)) as Box<dyn BaselineEncoder>,
            Box::new(CrpEncoder::new(cfg(), 3)),
            Box::new(IdLevelEncoder::new(cfg(), 16, 4)),
        ] {
            let qx = e.encode(&x);
            let qn = e.encode(&near);
            let qf = e.encode(&far);
            let d_near: f32 = qx.iter().zip(&qn).map(|(a, b)| (a - b).abs()).sum();
            let d_far: f32 = qx.iter().zip(&qf).map(|(a, b)| (a - b).abs()).sum();
            assert!(d_near < d_far, "{}: {d_near} !< {d_far}", e.name());
        }
    }

    #[test]
    fn kronecker_beats_all_baselines_on_cost() {
        let c = cfg();
        let k = kron_cost(&c);
        for e in [
            Box::new(RpEncoder::new(c.clone(), 2)) as Box<dyn BaselineEncoder>,
            Box::new(CrpEncoder::new(c.clone(), 3)),
            Box::new(IdLevelEncoder::new(c.clone(), 16, 4)),
        ] {
            assert!(k.ops < e.ops(), "{} ops", e.name());
            assert!(k.mem_bits < e.mem_bits(), "{} mem", e.name());
        }
    }

    #[test]
    fn rp_matches_software_kron_distribution() {
        // same scale config -> outputs should have comparable magnitude
        let c = cfg();
        let mut rng = crate::util::Rng::new(6);
        let x = gen::int8_vec(&mut rng, 64);
        let rp = RpEncoder::new(c.clone(), 7).encode(&x);
        let mut kron = SoftwareEncoder::random(c, 8);
        let kq = kron.encode_full(&x, 1).unwrap();
        let m_rp: f32 = rp.iter().map(|v| v.abs()).sum::<f32>() / rp.len() as f32;
        let m_k: f32 = kq.iter().map(|v| v.abs()).sum::<f32>() / kq.len() as f32;
        assert!(m_rp > 0.0 && m_k > 0.0);
        assert!(m_rp / m_k < 10.0 && m_k / m_rp < 10.0);
    }
}
