//! Per-op energy model, calibrated to Fig.10/Fig.11:
//!
//! * WCFE (BF16 CNN): 4.66 TFLOPS/W at 0.7 V -> 1.44 TFLOPS/W at 1.2 V
//! * HDC classifier:  3.78 TOPS/W  at 0.7 V -> 1.29 TOPS/W  at 1.2 V
//!
//! Energy per op scales as E(V) = E0 * (V/0.7)^alpha. Solving the paper's
//! measured endpoints: alpha_wcfe = ln(4.66/1.44)/ln(1.2/0.7) = 2.18,
//! alpha_hdc = ln(3.78/1.29)/ln(1.2/0.7) = 2.00 (textbook ~V^2 dynamic
//! energy; the WCFE's extra 0.18 absorbs its short-circuit/leakage share).
//! E0 = 1/EE(0.7V): 0.2146 pJ/flop (WCFE), 0.2646 pJ/op (HDC).

use crate::config::OperatingPoint;

/// Which clock/power domain an op executes in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Domain {
    Wcfe,
    Hdc,
}

/// Calibrated per-op energies at Vref = 0.7 V.
#[derive(Clone, Debug)]
pub struct EnergyModel {
    pub vref: f64,
    /// pJ per BF16 FLOP in the WCFE at Vref
    pub e0_wcfe_pj: f64,
    pub alpha_wcfe: f64,
    /// pJ per INT op in the HD module at Vref
    pub e0_hdc_pj: f64,
    pub alpha_hdc: f64,
    /// relative cost split inside one WCFE MAC: mult vs add (feeds the
    /// Fig.7 compute-reduction accounting; BF16 mult ~ 1.2x a wide add at
    /// this node — calibrated so the network-level CONV reduction lands on
    /// the paper's 2.1x)
    pub mult_add_ratio: f64,
    /// SRAM access energy per byte at Vref (pJ/B) — cache traffic term
    pub e_sram_pj_per_byte: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        let span: f64 = 1.2 / 0.7;
        EnergyModel {
            vref: 0.7,
            e0_wcfe_pj: 1.0 / 4.66, // pJ/flop == 1/(TFLOPS/W)
            alpha_wcfe: (4.66f64 / 1.44).ln() / span.ln(),
            e0_hdc_pj: 1.0 / 3.78,
            alpha_hdc: (3.78f64 / 1.29).ln() / span.ln(),
            mult_add_ratio: 1.2,
            e_sram_pj_per_byte: 0.08,
        }
    }
}

impl EnergyModel {
    /// pJ per op in `domain` at supply `v`.
    pub fn energy_per_op_pj(&self, domain: Domain, v: f64) -> f64 {
        let (e0, alpha) = match domain {
            Domain::Wcfe => (self.e0_wcfe_pj, self.alpha_wcfe),
            Domain::Hdc => (self.e0_hdc_pj, self.alpha_hdc),
        };
        e0 * (v / self.vref).powf(alpha)
    }

    /// Energy efficiency at an operating point: TFLOPS/W (WCFE) or TOPS/W
    /// (HDC) — the Fig.10a/b curves.
    pub fn efficiency(&self, domain: Domain, v: f64) -> f64 {
        1.0 / self.energy_per_op_pj(domain, v)
    }

    /// Joules for `ops` operations at voltage `v`.
    pub fn energy_j(&self, domain: Domain, ops: u64, v: f64) -> f64 {
        ops as f64 * self.energy_per_op_pj(domain, v) * 1e-12
    }

    /// Joules for `bytes` of SRAM traffic at voltage `v` (V^2 scaling).
    pub fn sram_energy_j(&self, bytes: u64, v: f64) -> f64 {
        bytes as f64 * self.e_sram_pj_per_byte * (v / self.vref).powi(2) * 1e-12
    }

    /// Peak throughput at an operating point, given the datapath's
    /// ops/cycle (Fig.10's peak-throughput axis).
    pub fn peak_throughput_gops(&self, ops_per_cycle: f64, op: OperatingPoint) -> f64 {
        ops_per_cycle * op.freq_mhz * 1e6 / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_endpoints_match_paper() {
        let m = EnergyModel::default();
        // Fig.11: WCFE 4.66 TFLOPS/W @0.7V, 1.44 @1.2V
        assert!((m.efficiency(Domain::Wcfe, 0.7) - 4.66).abs() < 0.01);
        assert!((m.efficiency(Domain::Wcfe, 1.2) - 1.44).abs() < 0.01);
        // HDC 3.78 TOPS/W @0.7V, 1.29 @1.2V
        assert!((m.efficiency(Domain::Hdc, 0.7) - 3.78).abs() < 0.01);
        assert!((m.efficiency(Domain::Hdc, 1.2) - 1.29).abs() < 0.01);
    }

    #[test]
    fn efficiency_monotone_decreasing_in_voltage() {
        let m = EnergyModel::default();
        let mut prev = f64::INFINITY;
        for i in 0..=10 {
            let v = 0.7 + 0.05 * i as f64;
            let ee = m.efficiency(Domain::Wcfe, v);
            assert!(ee < prev);
            prev = ee;
        }
    }

    #[test]
    fn alpha_near_v_squared() {
        let m = EnergyModel::default();
        assert!((m.alpha_hdc - 2.0).abs() < 0.01, "alpha_hdc {}", m.alpha_hdc);
        assert!((m.alpha_wcfe - 2.18).abs() < 0.01, "alpha_wcfe {}", m.alpha_wcfe);
    }

    #[test]
    fn energy_scales_linearly_with_ops() {
        let m = EnergyModel::default();
        let e1 = m.energy_j(Domain::Hdc, 1000, 0.9);
        let e2 = m.energy_j(Domain::Hdc, 2000, 0.9);
        assert!((e2 / e1 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn peak_throughput() {
        let m = EnergyModel::default();
        let op = OperatingPoint { voltage: 1.2, freq_mhz: 250.0 };
        // 256 ops/cycle at 250 MHz = 64 Gops
        assert!((m.peak_throughput_gops(256.0, op) - 64.0).abs() < 1e-9);
    }
}
