//! DVFS energy/latency model calibrated to the chip's silicon measurements
//! (Fig.10/Fig.11). See DESIGN.md "Substitutions" — this model stands in
//! for the 40 nm test chip; its calibration endpoints ARE the paper's
//! measured numbers, and every relative claim is derived from it.

pub mod model;
pub mod report;

pub use model::{Domain, EnergyModel};
pub use report::{comparison_table, DualModeEnergy, SotaChip};
