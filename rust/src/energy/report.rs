//! Fig.11 comparison-table data: the SOTA rows are published constants from
//! the paper's table; our row is produced by the calibrated model. The
//! bench `comparison_table` prints the whole table plus the headline
//! ratios (7.77x / 1.73x FE, 4.85x classifier).

use crate::energy::model::{Domain, EnergyModel};

/// Per-query energy accounting for dual-mode serving: the executor (and the
/// bench/loadgen measurement layer) precomputes the op counts of its
/// datapaths once — HDC encode+search ops per progressive segment, and the
/// clustered vs dense WCFE forward — then prices each served query by how
/// far the progressive search actually ran and whether the front-end fired.
/// Everything is priced by [`EnergyModel`] at one operating voltage, so
/// energy-per-query lines up with the paper's 0.7 V efficiency endpoints.
#[derive(Clone, Debug)]
pub struct DualModeEnergy {
    /// operating voltage the per-op energies are evaluated at
    pub v: f64,
    /// HDC ops (encode + search) per progressive-search segment
    pub hdc_ops_per_segment: u64,
    /// cluster-factored WCFE ops per image forward (0 without a front-end)
    pub fe_ops: u64,
    /// what a dense (un-clustered) forward would cost — the FE ops a
    /// bypassed query avoids
    pub fe_dense_ops: u64,
    /// the calibrated per-op energy model
    pub model: EnergyModel,
}

impl DualModeEnergy {
    /// Accounting at the paper's 0.7 V peak-efficiency point.
    pub fn new(hdc_ops_per_segment: u64, fe_ops: u64, fe_dense_ops: u64, v: f64) -> DualModeEnergy {
        DualModeEnergy {
            v,
            hdc_ops_per_segment,
            fe_ops,
            fe_dense_ops,
            model: EnergyModel::default(),
        }
    }

    /// Modeled energy of one classification that terminated after
    /// `segments_used` progressive segments, plus the WCFE forward when the
    /// query ran in normal mode.
    pub fn query_energy_j(&self, segments_used: usize, used_wcfe: bool) -> f64 {
        let hdc_ops = self.hdc_ops_per_segment * segments_used.max(1) as u64;
        let mut e = self.model.energy_j(Domain::Hdc, hdc_ops, self.v);
        if used_wcfe {
            e += self.model.energy_j(Domain::Wcfe, self.fe_ops, self.v);
        }
        e
    }

    /// The dense-FE ops a bypassed query avoided (the complexity-saving
    /// numerator loadgen/bench report).
    pub fn fe_ops_avoided(&self, used_wcfe: bool) -> u64 {
        if used_wcfe {
            // the clustered kernel still saved the dense-vs-clustered gap
            self.fe_dense_ops.saturating_sub(self.fe_ops)
        } else {
            self.fe_dense_ops
        }
    }
}

/// One comparison row (constants transcribed from Fig.11).
#[derive(Clone, Debug)]
pub struct SotaChip {
    pub name: &'static str,
    pub technology_nm: u32,
    pub learning_mode: &'static str,
    pub design: &'static str,
    pub encoder: &'static str,
    pub precision: &'static str,
    pub on_chip_mem_kb: u32,
    pub area_mm2: f64,
    pub freq_mhz: &'static str,
    pub supply_v: &'static str,
    /// scaled-to-40nm CNN/FE energy efficiency (TFLOPS/W), if reported
    pub ee_cnn: Option<f64>,
    /// scaled-to-40nm classifier EE (TOPS/W), if reported
    pub ee_classifier: Option<f64>,
}

/// The published SOTA rows (Fig.11, all EE scaled to 40 nm).
pub fn sota_rows() -> Vec<SotaChip> {
    vec![
        SotaChip {
            name: "ESSERC'24 [4]",
            technology_nm: 40,
            learning_mode: "FSL HDC",
            design: "Digital",
            encoder: "cRP-based",
            precision: "BF16/INT16",
            on_chip_mem_kb: 424,
            area_mm2: 11.3,
            freq_mhz: "100-250",
            supply_v: "0.9-1.2",
            ee_cnn: Some(2.69),
            ee_classifier: Some(0.78),
        },
        SotaChip {
            name: "VLSI'23 [8]",
            technology_nm: 28,
            learning_mode: "LET",
            design: "Digital + CIM",
            encoder: "-",
            precision: "BF16",
            on_chip_mem_kb: 329,
            area_mm2: 5.8,
            freq_mhz: "20-450",
            supply_v: "0.56-1.05",
            ee_cnn: Some(0.6), // 0.6-0.87 band; headline ratio uses 0.6
            ee_classifier: None,
        },
        SotaChip {
            name: "JSSC'23 [9]",
            technology_nm: 28,
            learning_mode: "Sparse BP",
            design: "Digital",
            encoder: "-",
            precision: "FP8/16",
            on_chip_mem_kb: 1280,
            area_mm2: 16.4,
            freq_mhz: "75-340",
            supply_v: "0.6-1.1",
            ee_cnn: Some(4.1),
            ee_classifier: None,
        },
        SotaChip {
            name: "JSSC'22 [3]",
            technology_nm: 40,
            learning_mode: "Low-rank BP",
            design: "Digital + CIM",
            encoder: "-",
            precision: "INT8",
            on_chip_mem_kb: 204 + 512,
            area_mm2: 29.2,
            freq_mhz: "200",
            supply_v: "1.1",
            ee_cnn: Some(1.1), // scaled INT8->BF16 equivalent (2.2 TOPS/W)
            ee_classifier: None,
        },
        SotaChip {
            name: "VLSI'21 [10]",
            technology_nm: 40,
            learning_mode: "OSL",
            design: "ReRAM CIM",
            encoder: "-",
            precision: "FP32",
            on_chip_mem_kb: 8,
            area_mm2: 0.2,
            freq_mhz: "200",
            supply_v: "-",
            ee_cnn: None,
            ee_classifier: Some(0.12),
        },
    ]
}

/// Our chip's row, derived from the calibrated model at peak efficiency.
pub fn our_row(model: &EnergyModel) -> SotaChip {
    SotaChip {
        name: "Clo-HDnn (this repro)",
        technology_nm: 40,
        learning_mode: "CL HDC",
        design: "Digital (simulated)",
        encoder: "Kronecker",
        precision: "BF16/INT1-8",
        on_chip_mem_kb: 200,
        area_mm2: 14.4,
        freq_mhz: "50-250",
        supply_v: "0.7-1.2",
        ee_cnn: Some(model.efficiency(Domain::Wcfe, 0.7)),
        ee_classifier: Some(model.efficiency(Domain::Hdc, 0.7)),
    }
}

/// Headline ratios of Fig.11's caption.
#[derive(Clone, Debug)]
pub struct HeadlineRatios {
    /// vs best HDC competitor [4]: paper 1.73x (FE)
    pub fe_vs_hdc_sota: f64,
    /// vs CIM competitor [8]: paper 7.77x (FE)
    pub fe_vs_cim_sota: f64,
    /// classifier vs [4]: paper 4.85x
    pub classifier_vs_sota: f64,
}

pub fn comparison_table(model: &EnergyModel) -> (SotaChip, Vec<SotaChip>, HeadlineRatios) {
    let ours = our_row(model);
    let rows = sota_rows();
    let ratios = HeadlineRatios {
        fe_vs_hdc_sota: ours.ee_cnn.unwrap() / rows[0].ee_cnn.unwrap(),
        fe_vs_cim_sota: ours.ee_cnn.unwrap() / rows[1].ee_cnn.unwrap(),
        classifier_vs_sota: ours.ee_classifier.unwrap() / rows[0].ee_classifier.unwrap(),
    };
    (ours, rows, ratios)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dual_mode_energy_prices_modes_and_segments() {
        let e = DualModeEnergy::new(1000, 50_000, 200_000, 0.7);
        let bypass_early = e.query_energy_j(4, false);
        let bypass_full = e.query_energy_j(16, false);
        let normal_full = e.query_energy_j(16, true);
        assert!(bypass_early > 0.0);
        assert!((bypass_full / bypass_early - 4.0).abs() < 1e-9);
        assert!(normal_full > bypass_full, "the FE forward must cost extra");
        assert_eq!(e.fe_ops_avoided(false), 200_000);
        assert_eq!(e.fe_ops_avoided(true), 150_000);
        // segments clamp at 1 so a degenerate report never prices at zero
        assert_eq!(e.query_energy_j(0, false), e.query_energy_j(1, false));
    }

    #[test]
    fn headline_ratios_match_paper() {
        let (_, _, r) = comparison_table(&EnergyModel::default());
        assert!((r.fe_vs_cim_sota - 7.77).abs() < 0.05, "{}", r.fe_vs_cim_sota);
        assert!((r.fe_vs_hdc_sota - 1.73).abs() < 0.05, "{}", r.fe_vs_hdc_sota);
        assert!((r.classifier_vs_sota - 4.85).abs() < 0.05, "{}", r.classifier_vs_sota);
    }

    #[test]
    fn our_row_is_first_hdc_cl_chip() {
        let (ours, rows, _) = comparison_table(&EnergyModel::default());
        assert_eq!(ours.learning_mode, "CL HDC");
        assert!(rows.iter().all(|r| r.learning_mode != "CL HDC"));
    }

    #[test]
    fn sota_rows_complete() {
        assert_eq!(sota_rows().len(), 5);
    }
}
