//! Continual-learning task streams (Fig.1/Fig.9 protocol).
//!
//! Task-incremental: the class set is partitioned into `n_tasks` groups that
//! arrive sequentially; each task exposes only its own classes' training
//! samples, while evaluation after task t covers ALL classes seen so far
//! (that is where catastrophic forgetting shows up for gradient learners).

use crate::data::Dataset;
use crate::util::Rng;

/// One task: the classes it introduces + its training sample indices.
#[derive(Clone, Debug)]
pub struct Task {
    pub id: usize,
    pub classes: Vec<usize>,
    pub train_indices: Vec<usize>,
}

/// Partition of a dataset into an ordered task sequence.
#[derive(Clone, Debug)]
pub struct TaskStream {
    pub tasks: Vec<Task>,
}

impl TaskStream {
    /// Split `train.classes` into `n_tasks` contiguous groups after a seeded
    /// shuffle of class order (deterministic per seed).
    pub fn class_incremental(train: &Dataset, n_tasks: usize, seed: u64) -> TaskStream {
        assert!(n_tasks >= 1 && n_tasks <= train.classes);
        let mut rng = Rng::new(seed);
        let order = rng.permutation(train.classes);
        let base = train.classes / n_tasks;
        let extra = train.classes % n_tasks;
        let mut tasks = Vec::with_capacity(n_tasks);
        let mut cursor = 0usize;
        for id in 0..n_tasks {
            let take = base + usize::from(id < extra);
            let classes: Vec<usize> = order[cursor..cursor + take].to_vec();
            cursor += take;
            let mut train_indices = train.indices_of_classes(&classes);
            rng.shuffle(&mut train_indices);
            tasks.push(Task { id, classes, train_indices });
        }
        TaskStream { tasks }
    }

    /// Classes seen up to and including task `t`.
    pub fn seen_classes(&self, t: usize) -> Vec<usize> {
        let mut v: Vec<usize> = self.tasks[..=t]
            .iter()
            .flat_map(|task| task.classes.iter().copied())
            .collect();
        v.sort_unstable();
        v
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_dataset(classes: usize, per_class: usize) -> Dataset {
        let n = classes * per_class;
        let y: Vec<u16> = (0..n).map(|i| (i % classes) as u16).collect();
        Dataset::from_parts(vec![0.0; n * 2], y, 2, classes).unwrap()
    }

    #[test]
    fn partitions_all_classes_exactly_once() {
        let ds = toy_dataset(10, 5);
        let ts = TaskStream::class_incremental(&ds, 4, 1);
        assert_eq!(ts.len(), 4);
        let mut all: Vec<usize> = ts.tasks.iter().flat_map(|t| t.classes.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
        // sizes 3,3,2,2
        let sizes: Vec<usize> = ts.tasks.iter().map(|t| t.classes.len()).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
    }

    #[test]
    fn task_indices_only_contain_task_classes() {
        let ds = toy_dataset(6, 4);
        let ts = TaskStream::class_incremental(&ds, 3, 2);
        for task in &ts.tasks {
            for &i in &task.train_indices {
                assert!(task.classes.contains(&ds.label(i)));
            }
            assert_eq!(task.train_indices.len(), task.classes.len() * 4);
        }
    }

    #[test]
    fn seen_classes_accumulates() {
        let ds = toy_dataset(6, 2);
        let ts = TaskStream::class_incremental(&ds, 3, 3);
        assert_eq!(ts.seen_classes(0).len(), 2);
        assert_eq!(ts.seen_classes(1).len(), 4);
        assert_eq!(ts.seen_classes(2), (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_per_seed() {
        let ds = toy_dataset(8, 3);
        let a = TaskStream::class_incremental(&ds, 4, 7);
        let b = TaskStream::class_incremental(&ds, 4, 7);
        for (ta, tb) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(ta.classes, tb.classes);
            assert_eq!(ta.train_indices, tb.train_indices);
        }
    }
}
