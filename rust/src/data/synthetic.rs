//! Hermetic synthetic workloads: built-in HD configs mirroring the paper's
//! bypass-mode operating points and deterministic Gaussian-blob datasets, so
//! the CLI, examples, benches, and tests all run with zero Python artifacts.
//!
//! Blob geometry matches the regime the unit tests train in (well-separated
//! class prototypes, σ=30 feature scale, σ=4 sample noise), which the HDC
//! pipeline classifies reliably after single-pass bundling.

use crate::config::HdConfig;
use crate::data::Dataset;
use crate::util::Rng;
use crate::Result;
use anyhow::bail;

/// Names of the built-in synthetic configs (all bypass-mode).
pub fn names() -> &'static [&'static str] {
    &["tiny", "isolet", "ucihar"]
}

/// A built-in synthetic config by name. Image (normal-mode) configs like
/// `cifar100` need the WCFE weights and therefore AOT artifacts.
pub fn config(name: &str) -> Result<HdConfig> {
    Ok(match name {
        // F=64, D=1024: the smoke-test operating point
        "tiny" => HdConfig::synthetic("tiny", 8, 8, 32, 32, 8, 10),
        // F=640 (617 padded), D=2048, 26 classes: the paper's ISOLET point
        "isolet" => HdConfig::synthetic("isolet", 32, 20, 64, 32, 16, 26),
        // F=576 (561 padded), D=2048, 6 classes: the paper's UCIHAR point
        "ucihar" => HdConfig::synthetic("ucihar", 24, 24, 64, 32, 16, 6),
        other => bail!(
            "no built-in synthetic config '{other}' (have {}); image-mode \
             configs such as cifar100 need AOT artifacts",
            names().join("|")
        ),
    })
}

/// Deterministic Gaussian-blob (train, test) pair for a config: one
/// prototype per class, `train_per_class` / `test_per_class` noisy draws.
pub fn blobs(
    cfg: &HdConfig,
    train_per_class: usize,
    test_per_class: usize,
    seed: u64,
) -> (Dataset, Dataset) {
    let mut rng = Rng::new(seed);
    let feat = cfg.features();
    let protos: Vec<Vec<f32>> = (0..cfg.classes)
        .map(|_| (0..feat).map(|_| rng.normal_f32() * 30.0).collect())
        .collect();
    // Classes are interleaved (round-robin) so that any prefix of the
    // dataset — callers routinely truncate with --samples / --learn caps —
    // stays class-balanced instead of silently dropping later classes.
    let draw = |per_class: usize, rng: &mut Rng| {
        let mut x = Vec::with_capacity(cfg.classes * per_class * feat);
        let mut y = Vec::with_capacity(cfg.classes * per_class);
        for _ in 0..per_class {
            for (c, p) in protos.iter().enumerate() {
                x.extend(p.iter().map(|&v| v + rng.normal_f32() * 4.0));
                y.push(c as u16);
            }
        }
        Dataset::from_parts(x, y, feat, cfg.classes).expect("blob parts are consistent")
    };
    let train = draw(train_per_class, &mut rng);
    let test = draw(test_per_class, &mut rng);
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_configs_validate() {
        for name in names() {
            let cfg = config(name).unwrap();
            assert!(cfg.validate().is_ok(), "{name}");
            assert!(!cfg.image, "{name} must be bypass-mode");
        }
        assert!(config("cifar100").is_err());
    }

    #[test]
    fn blobs_are_deterministic_and_shaped() {
        let cfg = config("tiny").unwrap();
        let (tr1, te1) = blobs(&cfg, 5, 3, 42);
        let (tr2, _) = blobs(&cfg, 5, 3, 42);
        assert_eq!(tr1.x, tr2.x);
        assert_eq!(tr1.n, 5 * cfg.classes);
        assert_eq!(te1.n, 3 * cfg.classes);
        assert_eq!(tr1.dim, cfg.features());
        assert_eq!(tr1.class_histogram(), vec![5; cfg.classes]);
    }

    #[test]
    fn blobs_are_learnable_by_the_hdc_pipeline() {
        use crate::hdc::encoder::SoftwareEncoder;
        use crate::hdc::{HdClassifier, ProgressiveSearch, Trainer};
        let cfg = config("tiny").unwrap();
        let (train, test) = blobs(&cfg, 8, 4, 7);
        let mut cl = HdClassifier::new(
            Box::new(SoftwareEncoder::random(cfg.clone(), 7)),
            ProgressiveSearch { tau: 0.4, min_segments: 1, ..Default::default() },
        );
        Trainer { retrain_epochs: 1 }.train_all(&mut cl, &train).unwrap();
        let report = cl
            .evaluate((0..test.n).map(|i| (test.sample(i).to_vec(), test.label(i))))
            .unwrap();
        assert!(report.accuracy > 0.9, "accuracy {}", report.accuracy);
    }
}
