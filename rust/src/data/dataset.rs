//! Reader for the "CLOD" dataset container written by
//! `python/compile/datasets.py` (see that module for the layout).

use anyhow::{anyhow, bail, Context, Result};
use std::io::Read;
use std::path::Path;

/// An in-memory labeled dataset. Features are f32 (u8 image payloads are
/// rescaled to [0,1] on load, matching the Python reader).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub y: Vec<u16>,
    pub n: usize,
    pub dim: usize,
    pub classes: usize,
    /// (h, w, c) when the payload is image shaped.
    pub image: Option<(usize, usize, usize)>,
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

impl Dataset {
    pub fn load(path: impl AsRef<Path>) -> Result<Dataset> {
        let path = path.as_ref();
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open dataset {}", path.display()))?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"CLOD" {
            bail!("{}: bad magic {:?}", path.display(), magic);
        }
        let version = read_u32(&mut f)?;
        if version != 1 {
            bail!("{}: unsupported version {version}", path.display());
        }
        let dtype = read_u32(&mut f)?;
        let n = read_u32(&mut f)? as usize;
        let dim = read_u32(&mut f)? as usize;
        let classes = read_u32(&mut f)? as usize;
        let h = read_u32(&mut f)? as usize;
        let w = read_u32(&mut f)? as usize;
        let c = read_u32(&mut f)? as usize;

        let mut ybytes = vec![0u8; 2 * n];
        f.read_exact(&mut ybytes)?;
        let y: Vec<u16> = ybytes
            .chunks_exact(2)
            .map(|b| u16::from_le_bytes([b[0], b[1]]))
            .collect();

        let x = match dtype {
            0 => {
                let mut buf = vec![0u8; 4 * n * dim];
                f.read_exact(&mut buf)?;
                buf.chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect()
            }
            1 => {
                let mut buf = vec![0u8; n * dim];
                f.read_exact(&mut buf)?;
                buf.iter().map(|&v| v as f32 / 255.0).collect()
            }
            other => bail!("{}: unknown dtype {other}", path.display()),
        };
        if let Some(&bad) = y.iter().find(|&&l| l as usize >= classes) {
            bail!("{}: label {bad} >= classes {classes}", path.display());
        }
        Ok(Dataset {
            x,
            y,
            n,
            dim,
            classes,
            image: if h > 0 { Some((h, w, c)) } else { None },
        })
    }

    /// Row view of sample `i`.
    pub fn sample(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }

    pub fn label(&self, i: usize) -> usize {
        self.y[i] as usize
    }

    /// Indices belonging to the given set of classes (CL task construction).
    pub fn indices_of_classes(&self, classes: &[usize]) -> Vec<usize> {
        (0..self.n)
            .filter(|&i| classes.contains(&(self.y[i] as usize)))
            .collect()
    }

    /// Per-class sample counts.
    pub fn class_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.classes];
        for &l in &self.y {
            h[l as usize] += 1;
        }
        h
    }

    /// Construct from raw parts (tests, synthetic workloads).
    pub fn from_parts(x: Vec<f32>, y: Vec<u16>, dim: usize, classes: usize) -> Result<Dataset> {
        if x.len() != y.len() * dim {
            return Err(anyhow!(
                "x len {} != n {} * dim {dim}",
                x.len(),
                y.len()
            ));
        }
        Ok(Dataset { n: y.len(), x, y, dim, classes, image: None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_f32_dataset(path: &Path, x: &[f32], y: &[u16], dim: u32, classes: u32) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(b"CLOD").unwrap();
        for v in [1u32, 0, y.len() as u32, dim, classes, 0, 0, 0] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        for l in y {
            f.write_all(&l.to_le_bytes()).unwrap();
        }
        for v in x {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
    }

    #[test]
    fn roundtrip_f32() {
        let dir = std::env::temp_dir().join("clo_hdnn_test_ds");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.bin");
        let x = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let y = vec![0u16, 2];
        write_f32_dataset(&p, &x, &y, 3, 3);
        let ds = Dataset::load(&p).unwrap();
        assert_eq!(ds.n, 2);
        assert_eq!(ds.dim, 3);
        assert_eq!(ds.sample(1), &[4.0, 5.0, 6.0]);
        assert_eq!(ds.label(1), 2);
        assert_eq!(ds.class_histogram(), vec![1, 0, 1]);
        assert_eq!(ds.indices_of_classes(&[2]), vec![1]);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("clo_hdnn_test_ds2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOPE00000000000000000000000000000000").unwrap();
        assert!(Dataset::load(&p).is_err());
    }

    #[test]
    fn rejects_label_out_of_range() {
        let dir = std::env::temp_dir().join("clo_hdnn_test_ds3");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.bin");
        write_f32_dataset(&p, &[0.0, 0.0], &[5, 0], 1, 2);
        assert!(Dataset::load(&p).is_err());
    }

    #[test]
    fn from_parts_validates() {
        assert!(Dataset::from_parts(vec![0.0; 6], vec![0, 1], 3, 2).is_ok());
        assert!(Dataset::from_parts(vec![0.0; 5], vec![0, 1], 3, 2).is_err());
    }
}
