//! Dataset + named-tensor containers (shared binary formats with the Python
//! build pipeline), continual-learning task streams, and hermetic synthetic
//! workloads for artifact-free runs.

pub mod dataset;
pub mod scenario;
pub mod stream;
pub mod synthetic;
pub mod tensors;

pub use dataset::Dataset;
pub use stream::{Task, TaskStream};
pub use tensors::{Tensor, TensorFile};
