//! Dataset + named-tensor containers (shared binary formats with the Python
//! build pipeline) and continual-learning task streams.

pub mod dataset;
pub mod stream;
pub mod tensors;

pub use dataset::Dataset;
pub use stream::{Task, TaskStream};
pub use tensors::TensorFile;
