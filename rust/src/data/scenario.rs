//! Self-contained dual-mode scenario matrix: MNIST/ISOLET/UCIHAR-style
//! image workloads with an explicit easy/hard axis, shared by the CL
//! harness, `bench --dualmode`, and `loadgen --payload image|mix`.
//!
//! Each scenario fixes one geometry where the raw pixel count equals the
//! serving config's feature count, so the same sample is valid in both
//! operating modes: bypass mode feeds the pixels straight to the HDC
//! encoder, normal mode runs them through a seeded clustered WCFE first
//! (the paper's dual-mode split — skip the feature extractor on easy
//! datasets, engage it on hard ones). The easy/hard axis only changes the
//! per-sample noise around class-distinct brightness prototypes: easy
//! samples sit far apart (wide top-2 margins, confident bypass), hard
//! samples overlap (thin margins, confidence-policy escalation).
//!
//! Everything is seed-deterministic: two processes building the same
//! scenario get bit-identical datasets and (via the recorded WCFE seed)
//! bit-identical front-ends — what the loadgen↔server split and the CI
//! escalation gates rely on.

use crate::config::HdConfig;
use crate::data::Dataset;
use crate::util::Rng;
use crate::Result;
use anyhow::bail;

/// Per-sample noise σ of the easy axis: well under the brightness-band
/// spacing, so bypass classification is confident.
pub const EASY_NOISE: f32 = 0.04;
/// Per-sample noise σ of the hard axis: comparable to the band spacing,
/// so top-2 margins thin out and escalation fires.
pub const HARD_NOISE: f32 = 0.28;
/// Input quantization scale shared by every scenario config: pixels live
/// in [0,1] and seeded-WCFE features are small, so the serving quantizer
/// must divide by a small scale to stay discriminative in INT8.
pub const SCENARIO_SCALE_X: f32 = 0.02;

/// One cell of the scenario matrix: a dataset family at one difficulty,
/// carrying everything a dual-mode server needs — the HD config, the
/// image geometry, and the seeded-WCFE build parameters.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// matrix cell name, `<family>-easy` / `<family>-hard`
    pub name: String,
    /// dataset family (`mnist` | `isolet` | `ucihar` style)
    pub family: &'static str,
    /// the hard end of the difficulty axis?
    pub hard: bool,
    /// serving config; `cfg.features()` equals the pixel count
    pub cfg: HdConfig,
    /// square image side in pixels
    pub image_hw: usize,
    /// image channels
    pub image_c: usize,
    /// seeded-WCFE conv output channels, in layer order
    pub channels: Vec<usize>,
    /// seeded-WCFE codebook size per conv layer
    pub clusters: usize,
    /// seed for both the dataset draw and the WCFE weights (shared per
    /// family, so easy and hard differ only in sample noise)
    pub seed: u64,
    /// per-sample Gaussian noise σ around the class prototype
    pub noise: f32,
}

impl Scenario {
    /// Raw pixel count of one sample (= `cfg.features()` by construction).
    pub fn pixels(&self) -> usize {
        self.image_hw * self.image_hw * self.image_c
    }

    /// Deterministic (train, test) image datasets: per class, a prototype
    /// on a class-distinct brightness band with fixed per-pixel texture;
    /// samples add the difficulty axis' noise. Classes are round-robin
    /// interleaved so truncated prefixes stay class-balanced (the same
    /// contract as [`synthetic::blobs`](crate::data::synthetic::blobs)).
    pub fn images(&self, train_per_class: usize, test_per_class: usize) -> (Dataset, Dataset) {
        let mut rng = Rng::new(self.seed);
        let n_px = self.pixels();
        let classes = self.cfg.classes;
        // brightness bands spread over [0.08, 0.92]; texture keeps classes
        // apart pixel-wise even when bands sit close (26-class families),
        // while the band keeps them apart after the WCFE's global pooling
        let protos: Vec<Vec<f32>> = (0..classes)
            .map(|c| {
                let base = 0.08 + 0.84 * c as f32 / (classes - 1).max(1) as f32;
                (0..n_px)
                    .map(|_| (base + rng.normal_f32() * 0.12).clamp(0.0, 1.0))
                    .collect()
            })
            .collect();
        let noise = self.noise;
        let mut draw = |per_class: usize, rng: &mut Rng| {
            let mut x = Vec::with_capacity(classes * per_class * n_px);
            let mut y = Vec::with_capacity(classes * per_class);
            for _ in 0..per_class {
                for (c, p) in protos.iter().enumerate() {
                    x.extend(p.iter().map(|&v| (v + rng.normal_f32() * noise).clamp(0.0, 1.0)));
                    y.push(c as u16);
                }
            }
            Dataset::from_parts(x, y, n_px, classes).expect("scenario parts are consistent")
        };
        let train = draw(train_per_class, &mut rng);
        let test = draw(test_per_class, &mut rng);
        (train, test)
    }
}

/// Names of the matrix cells, easy before hard within each family.
pub fn names() -> &'static [&'static str] {
    &[
        "mnist-easy",
        "mnist-hard",
        "isolet-easy",
        "isolet-hard",
        "ucihar-easy",
        "ucihar-hard",
    ]
}

/// One family axis: (family, image_hw, image_c, f1, f2, d1, d2, segments,
/// classes, seed). Geometry invariant: hw²·c == f1·f2, and hw survives
/// one maxpool halving per conv layer.
fn family(name: &str) -> Option<(&'static str, usize, usize, [usize; 6], u64)> {
    match name {
        // 16×16×1 = 256 px | F=256 D=1024 seg=8, 10 classes
        "mnist" => Some(("mnist", 16, 1, [16, 16, 32, 32, 8, 10], 101)),
        // 16×16×2 = 512 px | F=512 D=2048 seg=16, 26 classes
        "isolet" => Some(("isolet", 16, 2, [32, 16, 64, 32, 16, 26], 202)),
        // 24×24×1 = 576 px | F=576 D=2048 seg=16, 6 classes
        "ucihar" => Some(("ucihar", 24, 1, [24, 24, 64, 32, 16, 6], 303)),
        _ => None,
    }
}

/// A matrix cell by name (`mnist-easy`, `ucihar-hard`, ...).
pub fn get(name: &str) -> Result<Scenario> {
    let (fam_name, difficulty) = match name.rsplit_once('-') {
        Some(parts) => parts,
        None => bail!("no scenario '{name}' (have {})", names().join("|")),
    };
    let hard = match difficulty {
        "easy" => false,
        "hard" => true,
        _ => bail!("no scenario '{name}' (have {})", names().join("|")),
    };
    let (family, hw, c, [f1, f2, d1, d2, segments, classes], seed) = match family(fam_name) {
        Some(f) => f,
        None => bail!("no scenario '{name}' (have {})", names().join("|")),
    };
    let mut cfg = HdConfig::synthetic(name, f1, f2, d1, d2, segments, classes);
    cfg.scale_x = SCENARIO_SCALE_X;
    Ok(Scenario {
        name: name.to_string(),
        family,
        hard,
        cfg,
        image_hw: hw,
        image_c: c,
        // conv widths well above the codebook size: weight clustering
        // only saves compute when c_out >> clusters (K centroid multiplies
        // replace c_out dense ones per input scalar)
        channels: vec![16, 32],
        clusters: 8,
        seed,
        noise: if hard { HARD_NOISE } else { EASY_NOISE },
    })
}

/// The whole matrix, in [`names`] order.
pub fn matrix() -> Vec<Scenario> {
    names().iter().map(|n| get(n).expect("built-in scenarios resolve")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_cells_resolve_and_validate() {
        for sc in matrix() {
            assert!(sc.cfg.validate().is_ok(), "{}", sc.name);
            // bypass feasibility: pixels double as the feature vector
            assert_eq!(sc.pixels(), sc.cfg.features(), "{}", sc.name);
            // normal feasibility: the image survives one halving per layer
            assert_eq!(sc.image_hw % (1 << sc.channels.len()), 0, "{}", sc.name);
            assert_eq!(sc.cfg.scale_x, SCENARIO_SCALE_X);
            // the complexity-savings premise the energy report relies on:
            // the cell's clustered FE is strictly cheaper than dense
            let fe = crate::wcfe::ClusteredWcfe::cluster(
                crate::wcfe::WcfeModel::seeded(
                    sc.image_hw,
                    sc.image_c,
                    &sc.channels,
                    sc.cfg.features(),
                    sc.seed,
                ),
                sc.clusters,
            );
            assert!(fe.clustered_ops() < fe.dense_ops(), "{}", sc.name);
        }
        assert!(get("mnist-medium").is_err());
        assert!(get("cifar-easy").is_err());
        assert!(get("mnist").is_err());
    }

    #[test]
    fn easy_and_hard_share_prototypes_but_not_noise() {
        let easy = get("mnist-easy").unwrap();
        let hard = get("mnist-hard").unwrap();
        assert_eq!(easy.seed, hard.seed);
        assert!(easy.noise < hard.noise);
        let (e_train, _) = easy.images(3, 2);
        let (h_train, _) = hard.images(3, 2);
        assert_eq!(e_train.n, h_train.n);
        assert_ne!(e_train.x, h_train.x, "noise must differ across the axis");
        // determinism: the same cell twice is bit-identical
        let (e2, _) = easy.images(3, 2);
        assert_eq!(e_train.x, e2.x);
    }

    #[test]
    fn images_are_shaped_balanced_and_in_range() {
        let sc = get("ucihar-hard").unwrap();
        let (train, test) = sc.images(5, 3);
        assert_eq!(train.n, 5 * sc.cfg.classes);
        assert_eq!(test.n, 3 * sc.cfg.classes);
        assert_eq!(train.dim, sc.pixels());
        assert_eq!(train.class_histogram(), vec![5; sc.cfg.classes]);
        assert!(train.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn easy_scenarios_separate_in_pixel_space() {
        // the bypass-mode premise: raw pixels of an easy cell classify by
        // nearest prototype distance alone
        let sc = get("mnist-easy").unwrap();
        let (train, test) = sc.images(1, 4);
        let correct = (0..test.n)
            .filter(|&i| {
                let s = test.sample(i);
                let nearest = (0..train.n)
                    .min_by_key(|&j| {
                        train.sample(j)
                            .iter()
                            .zip(s)
                            .map(|(a, b)| ((a - b).abs() * 1e4) as u64)
                            .sum::<u64>()
                    })
                    .unwrap();
                train.label(nearest) == test.label(i)
            })
            .count();
        assert!(correct * 10 >= test.n * 9, "{correct}/{}", test.n);
    }
}
