//! Reader for the "CLOW" named-tensor container written by
//! `python/compile/weights_io.py` (Kronecker factors, WCFE weights/codebook,
//! golden test fixtures).

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn dims(&self) -> &[usize] {
        match self {
            Tensor::F32 { dims, .. } | Tensor::I32 { dims, .. } => dims,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[derive(Clone, Debug, Default)]
pub struct TensorFile {
    pub tensors: BTreeMap<String, Tensor>,
}

impl TensorFile {
    pub fn load(path: impl AsRef<Path>) -> Result<TensorFile> {
        let path = path.as_ref();
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open tensor file {}", path.display()))?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"CLOW" {
            bail!("{}: bad magic", path.display());
        }
        let mut hdr = [0u8; 8];
        f.read_exact(&mut hdr)?;
        let version = u32::from_le_bytes(hdr[0..4].try_into().unwrap());
        let count = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
        if version != 1 {
            bail!("{}: unsupported version {version}", path.display());
        }
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let mut nlen = [0u8; 2];
            f.read_exact(&mut nlen)?;
            let mut name = vec![0u8; u16::from_le_bytes(nlen) as usize];
            f.read_exact(&mut name)?;
            let name = String::from_utf8(name)?;
            let mut meta = [0u8; 5];
            f.read_exact(&mut meta)?;
            let dtype = meta[0];
            let ndim = u32::from_le_bytes(meta[1..5].try_into().unwrap()) as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                let mut d = [0u8; 4];
                f.read_exact(&mut d)?;
                dims.push(u32::from_le_bytes(d) as usize);
            }
            let count: usize = dims.iter().product::<usize>().max(if ndim == 0 { 1 } else { 0 });
            let mut buf = vec![0u8; 4 * count];
            f.read_exact(&mut buf)?;
            let tensor = match dtype {
                0 => Tensor::F32 {
                    dims,
                    data: buf
                        .chunks_exact(4)
                        .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                        .collect(),
                },
                1 => Tensor::I32 {
                    dims,
                    data: buf
                        .chunks_exact(4)
                        .map(|b| i32::from_le_bytes(b.try_into().unwrap()))
                        .collect(),
                },
                other => bail!("{}: unknown dtype {other}", path.display()),
            };
            tensors.insert(name, tensor);
        }
        Ok(TensorFile { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow!("missing tensor {name}"))
    }

    pub fn f32(&self, name: &str) -> Result<&[f32]> {
        self.get(name)?.as_f32()
    }

    pub fn i32(&self, name: &str) -> Result<&[i32]> {
        self.get(name)?.as_i32()
    }

    /// f32 tensor with shape check.
    pub fn f32_shaped(&self, name: &str, dims: &[usize]) -> Result<&[f32]> {
        let t = self.get(name)?;
        if t.dims() != dims {
            bail!("tensor {name}: dims {:?} != expected {:?}", t.dims(), dims);
        }
        t.as_f32()
    }

    /// Add (or replace) an f32 tensor.
    pub fn insert_f32(&mut self, name: &str, dims: &[usize], data: Vec<f32>) {
        self.tensors
            .insert(name.to_string(), Tensor::F32 { dims: dims.to_vec(), data });
    }

    /// Write the container in the same CLOW v1 layout [`TensorFile::load`]
    /// reads (used by the Rust-side golden-fixture generator; byte-compatible
    /// with `python/compile/weights_io.py`).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        use std::io::Write;
        let path = path.as_ref();
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("create tensor file {}", path.display()))?,
        );
        f.write_all(b"CLOW")?;
        f.write_all(&1u32.to_le_bytes())?;
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            let nb = name.as_bytes();
            if nb.len() > u16::MAX as usize {
                bail!("tensor name '{name}' too long");
            }
            f.write_all(&(nb.len() as u16).to_le_bytes())?;
            f.write_all(nb)?;
            let dtype: u8 = match t {
                Tensor::F32 { .. } => 0,
                Tensor::I32 { .. } => 1,
            };
            f.write_all(&[dtype])?;
            let dims = t.dims();
            f.write_all(&(dims.len() as u32).to_le_bytes())?;
            for &d in dims {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            match t {
                Tensor::F32 { data, .. } => {
                    for v in data {
                        f.write_all(&v.to_le_bytes())?;
                    }
                }
                Tensor::I32 { data, .. } => {
                    for v in data {
                        f.write_all(&v.to_le_bytes())?;
                    }
                }
            }
        }
        f.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_file(path: &Path) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(b"CLOW").unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        // "m" f32 (2,2)
        f.write_all(&1u16.to_le_bytes()).unwrap();
        f.write_all(b"m").unwrap();
        f.write_all(&[0u8]).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        // "i" i32 (3,)
        f.write_all(&1u16.to_le_bytes()).unwrap();
        f.write_all(b"i").unwrap();
        f.write_all(&[1u8]).unwrap();
        f.write_all(&1u32.to_le_bytes()).unwrap();
        f.write_all(&3u32.to_le_bytes()).unwrap();
        for v in [7i32, -1, 0] {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
    }

    #[test]
    fn reads_mixed_tensors() {
        let dir = std::env::temp_dir().join("clo_hdnn_test_tf");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.bin");
        write_file(&p);
        let tf = TensorFile::load(&p).unwrap();
        assert_eq!(tf.f32("m").unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(tf.get("m").unwrap().dims(), &[2, 2]);
        assert_eq!(tf.i32("i").unwrap(), &[7, -1, 0]);
        assert!(tf.f32("i").is_err());
        assert!(tf.get("absent").is_err());
        assert!(tf.f32_shaped("m", &[2, 2]).is_ok());
        assert!(tf.f32_shaped("m", &[4]).is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("clo_hdnn_test_tf_save");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rt.bin");
        let mut tf = TensorFile::default();
        tf.insert_f32("a", &[2, 3], vec![1.0, -2.5, 3.0, 0.0, 4.5, -6.0]);
        tf.insert_f32("scale", &[1], vec![24.0]);
        tf.tensors.insert(
            "idx".to_string(),
            Tensor::I32 { dims: vec![3], data: vec![7, -1, 0] },
        );
        tf.save(&p).unwrap();
        let back = TensorFile::load(&p).unwrap();
        assert_eq!(back.tensors, tf.tensors);
        assert_eq!(back.f32_shaped("a", &[2, 3]).unwrap()[1], -2.5);
        assert_eq!(back.i32("idx").unwrap(), &[7, -1, 0]);
    }
}
