//! [`PjrtBackend`]: the production [`HdBackend`] executing the AOT-lowered
//! Pallas/JAX artifacts (encode_segment / encode_full / search_seg) through
//! the PJRT engine. Holds `Rc` executable handles so several backends can
//! share one engine's compilation cache.

use crate::config::HdConfig;
use crate::hdc::HdBackend;
use crate::runtime::engine::{Arg, Engine, Executable};
use crate::Result;
use anyhow::bail;
use std::rc::Rc;

pub struct PjrtBackend {
    cfg: HdConfig,
    enc_seg: Rc<Executable>,
    enc_full: Rc<Executable>,
    search_seg: Rc<Executable>,
    /// batch size the handles were lowered for
    batch: usize,
}

impl PjrtBackend {
    /// Build from an engine for the named config and batch size (an
    /// executable set for that batch must exist in the manifest).
    pub fn new(engine: &mut Engine, config: &str, batch: usize) -> Result<PjrtBackend> {
        let cfg = engine.manifest.config(config)?.clone();
        if !cfg.batches.contains(&batch) {
            bail!(
                "config {config} has no batch-{batch} executables (has {:?})",
                cfg.batches
            );
        }
        Ok(PjrtBackend {
            enc_seg: engine.executable(&format!("encode_segment_{config}_b{batch}"))?,
            enc_full: engine.executable(&format!("encode_full_{config}_b{batch}"))?,
            search_seg: engine.executable(&format!("search_seg_{config}_b{batch}"))?,
            cfg,
            batch,
        })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Pad a partial batch up to the lowered batch size (replicating the
    /// last row) and run; callers slice the result back down. An empty batch
    /// is an error: there is no last row to replicate (and `batch - 1` would
    /// underflow), and the guard matches `NativeBackend`.
    fn pad(&self, xs: &[f32], batch: usize, width: usize) -> Result<Vec<f32>> {
        if batch == 0 {
            bail!("empty batch (batch must be >= 1)");
        }
        let mut padded = Vec::with_capacity(self.batch * width);
        padded.extend_from_slice(xs);
        let last = &xs[(batch - 1) * width..batch * width];
        for _ in batch..self.batch {
            padded.extend_from_slice(last);
        }
        Ok(padded)
    }
}

impl HdBackend for PjrtBackend {
    fn cfg(&self) -> &HdConfig {
        &self.cfg
    }

    fn encode_segment(&mut self, xs: &[f32], batch: usize, seg: usize) -> Result<Vec<f32>> {
        let feat = self.cfg.features();
        if batch > self.batch || xs.len() != batch * feat {
            bail!("encode_segment: bad batch {batch} / len {}", xs.len());
        }
        if seg >= self.cfg.segments {
            bail!("segment {seg} out of range");
        }
        let padded = self.pad(xs, batch, feat)?;
        let out = self.enc_seg.run(&[
            Arg::F32(&padded, &[self.batch, feat]),
            Arg::I32(seg as i32),
        ])?;
        Ok(out[..batch * self.cfg.seg_len()].to_vec())
    }

    fn encode_full(&mut self, xs: &[f32], batch: usize) -> Result<Vec<f32>> {
        let feat = self.cfg.features();
        if batch > self.batch || xs.len() != batch * feat {
            bail!("encode_full: bad batch {batch} / len {}", xs.len());
        }
        let padded = self.pad(xs, batch, feat)?;
        let out = self
            .enc_full
            .run(&[Arg::F32(&padded, &[self.batch, feat])])?;
        Ok(out[..batch * self.cfg.dim()].to_vec())
    }

    fn search(
        &mut self,
        qs: &[f32],
        batch: usize,
        chvs: &[f32],
        classes: usize,
        len: usize,
    ) -> Result<Vec<f32>> {
        if len != self.cfg.seg_len() || classes != self.cfg.classes {
            bail!(
                "search executable lowered for (C={}, L={}), got (C={classes}, L={len})",
                self.cfg.classes,
                self.cfg.seg_len()
            );
        }
        if batch > self.batch || qs.len() != batch * len {
            bail!("search: bad batch {batch} / len {}", qs.len());
        }
        let padded = self.pad(qs, batch, len)?;
        let out = self.search_seg.run(&[
            Arg::F32(&padded, &[self.batch, len]),
            Arg::F32(chvs, &[classes, len]),
        ])?;
        Ok(out[..batch * classes].to_vec())
    }
}
