//! Execution runtime behind the [`crate::hdc::HdBackend`] trait.
//!
//! Two interchangeable backends:
//! * [`NativeBackend`] (default) — pure Rust, hermetic: no Python, no PJRT,
//!   no artifacts required. This is what CI builds and tests.
//! * `PjrtBackend` (`--features pjrt`) — loads AOT artifacts (HLO text
//!   lowered from JAX/Pallas by `python/compile/aot.py`), compiles once per
//!   process via the PJRT C API, and executes them on the hot path. Python
//!   never runs here.
//!
//! [`Manifest`] (the artifact catalogue) is plain JSON parsing and is always
//! available; only the engine/executable layer needs the `xla` bindings.

#[cfg(feature = "pjrt")]
pub mod backend;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod manifest;
pub mod native;

#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
#[cfg(feature = "pjrt")]
pub use engine::{Arg, Engine, Executable};
pub use manifest::{KnowledgeMeta, Manifest};
pub use native::NativeBackend;
