//! PJRT runtime: load AOT artifacts (HLO text lowered from JAX/Pallas by
//! `python/compile/aot.py`), compile once per process, execute on the hot
//! path. Python never runs here.

pub mod backend;
pub mod engine;
pub mod manifest;

pub use backend::PjrtBackend;
pub use engine::{Arg, Engine, Executable};
pub use manifest::Manifest;
