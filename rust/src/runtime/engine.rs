//! PJRT execution engine: loads HLO-text artifacts, compiles them once on
//! the CPU PJRT client, and executes them from the request path.
//!
//! Pattern from /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `client.compile` -> `execute`, with
//! outputs unwrapped via `to_tuple1` (everything is lowered with
//! return_tuple=True).

use crate::runtime::manifest::Manifest;
use crate::Result;
use anyhow::{anyhow, bail, Context};
use std::collections::HashMap;
use std::rc::Rc;

/// Executable handle + its manifest shapes.
pub struct Executable {
    pub exe: xla::PjRtLoadedExecutable,
    pub inputs: Vec<Vec<usize>>,
    pub out: Vec<usize>,
    pub name: String,
    /// execution counter (perf accounting)
    pub calls: std::cell::Cell<u64>,
}

/// An argument for [`Executable::run`].
pub enum Arg<'a> {
    /// f32 tensor with explicit dims
    F32(&'a [f32], &'a [usize]),
    /// i32 scalar
    I32(i32),
}

impl Executable {
    /// Execute with shape-checked arguments; returns the flat f32 output.
    pub fn run(&self, args: &[Arg]) -> Result<Vec<f32>> {
        if args.len() != self.inputs.len() {
            bail!(
                "{}: got {} args, expected {}",
                self.name,
                args.len(),
                self.inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(args.len());
        for (i, arg) in args.iter().enumerate() {
            match arg {
                Arg::F32(data, dims) => {
                    let want: usize = self.inputs[i].iter().product();
                    if data.len() != want {
                        bail!(
                            "{}: arg {i} has {} elems, manifest says {:?}",
                            self.name,
                            data.len(),
                            self.inputs[i]
                        );
                    }
                    // single-copy literal construction (PERF: vec1+reshape
                    // copied the buffer twice; see EXPERIMENTS.md §Perf)
                    let bytes = unsafe {
                        std::slice::from_raw_parts(
                            data.as_ptr() as *const u8,
                            std::mem::size_of_val(*data),
                        )
                    };
                    literals.push(xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::F32,
                        dims,
                        bytes,
                    )?);
                }
                Arg::I32(v) => literals.push(xla::Literal::scalar(*v)),
            }
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let lit = result[0][0].to_literal_sync()?;
        let out = lit.to_tuple1()?;
        Ok(out.to_vec::<f32>()?).map(|v| {
            self.calls.set(self.calls.get() + 1);
            v
        })
    }
}

/// Loads + compiles + caches executables for one artifact directory.
pub struct Engine {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: HashMap<String, Rc<Executable>>,
}

impl Engine {
    /// Open the artifact directory and start a CPU PJRT client.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { client, manifest, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an executable by manifest name.
    pub fn executable(&mut self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let meta = self.manifest.executable(name)?.clone();
        let path = self.manifest.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow!("non-utf8 path {}", path.display()))?,
        )
        .with_context(|| format!("parse HLO {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {name}"))?;
        let handle = Rc::new(Executable {
            exe,
            inputs: meta.inputs,
            out: meta.out,
            name: name.to_string(),
            calls: std::cell::Cell::new(0),
        });
        self.cache.insert(name.to_string(), handle.clone());
        Ok(handle)
    }

    /// Convenience: run by name with f32 tensors shaped per the manifest.
    pub fn run_f32(&mut self, name: &str, tensors: &[&[f32]]) -> Result<Vec<f32>> {
        let exe = self.executable(name)?;
        let shapes = exe.inputs.clone();
        let args: Vec<Arg> = tensors
            .iter()
            .zip(shapes.iter())
            .map(|(t, s)| Arg::F32(t, s))
            .collect();
        exe.run(&args)
    }

    /// Number of distinct compiled executables (startup-cost accounting).
    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }
}
