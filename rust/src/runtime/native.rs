//! [`NativeBackend`]: the default, hermetic [`HdBackend`] — pure Rust, no
//! PJRT, no Python artifacts required. It wraps [`SoftwareEncoder`] (the
//! bit-exact software twin of the AOT Pallas kernels) behind the same
//! construction/batching surface as `PjrtBackend`, so the coordinator, CLI,
//! benches, and tests are backend-agnostic:
//!
//! * [`NativeBackend::seeded`] — random ±1 Kronecker factors from a seed
//!   (synthetic configs, tests, artifact-free serving);
//! * [`NativeBackend::from_manifest`] / [`NativeBackend::from_artifacts`] —
//!   the production factors from `hd_factors_<config>.bin`, matching what
//!   the PJRT executables were lowered with.
//!
//! Unlike `PjrtBackend`, no executable set is lowered per batch size: any
//! batch in `1..=max_batch` runs directly. `batch == 0` is rejected (the
//! same guard `PjrtBackend::pad` applies) rather than silently returning an
//! empty tensor.

use crate::config::HdConfig;
use crate::data::TensorFile;
use crate::hdc::encoder::SoftwareEncoder;
use crate::hdc::{packed, HdBackend};
use crate::runtime::Manifest;
use crate::util::pool::WorkerPool;
use crate::Result;
use anyhow::bail;
use std::path::Path;

pub struct NativeBackend {
    inner: SoftwareEncoder,
    /// largest accepted batch (API parity with the lowered PJRT handles)
    max_batch: usize,
    /// worker-thread budget for one call (rows of a batched encode, class
    /// row-blocks of a packed search); owned by the backend, which is itself
    /// owned by the executor thread. Defaults to `CLO_HDNN_THREADS` or 1;
    /// the coordinator/CLI raise it via `set_parallelism`.
    pool: WorkerPool,
}

impl NativeBackend {
    /// Wrap an existing encoder; `max_batch` must be >= 1.
    pub fn new(inner: SoftwareEncoder, max_batch: usize) -> Result<NativeBackend> {
        if max_batch == 0 {
            bail!("NativeBackend: max_batch must be >= 1");
        }
        Ok(NativeBackend { inner, max_batch, pool: WorkerPool::from_env_or(1) })
    }

    /// Random ±1 Kronecker factors from a seed (no artifacts needed).
    pub fn seeded(cfg: HdConfig, seed: u64, max_batch: usize) -> Result<NativeBackend> {
        NativeBackend::new(SoftwareEncoder::random(cfg, seed), max_batch)
    }

    /// Like [`NativeBackend::seeded`], but holding the factors as
    /// **rematerialized** seed-derived planes: only the plane seeds stay
    /// resident and the sign-GEMM kernels regenerate rows on the fly, so a
    /// registry of many large-D models scales with models × classes instead
    /// of models × D × F. Encodes are bit-identical to a backend built on
    /// [`SoftwareEncoder::random_remat_materialized`] with the same seed.
    pub fn seeded_remat(cfg: HdConfig, seed: u64, max_batch: usize) -> Result<NativeBackend> {
        NativeBackend::new(SoftwareEncoder::random_remat(cfg, seed), max_batch)
    }

    /// Whether the encoder's factor planes are rematerialized.
    pub fn is_remat(&self) -> bool {
        self.inner.is_remat()
    }

    /// Resident factor memory in bytes (O(1) for rematerialized planes).
    pub fn factor_bytes(&self) -> usize {
        self.inner.factor_bytes()
    }

    /// Load the production factors referenced by an already-open manifest.
    pub fn from_manifest(
        manifest: &Manifest,
        config: &str,
        max_batch: usize,
    ) -> Result<NativeBackend> {
        let cfg = manifest.config(config)?.clone();
        let tf = TensorFile::load(manifest.dir.join(format!("hd_factors_{config}.bin")))?;
        let enc = SoftwareEncoder::new(
            cfg.clone(),
            tf.f32_shaped("a", &[cfg.d1, cfg.f1])?.to_vec(),
            tf.f32_shaped("b", &[cfg.d2, cfg.f2])?.to_vec(),
        )?;
        NativeBackend::new(enc, max_batch)
    }

    /// Open an artifact directory and load the named config's factors.
    pub fn from_artifacts(
        dir: impl AsRef<Path>,
        config: &str,
        max_batch: usize,
    ) -> Result<NativeBackend> {
        let manifest = Manifest::load(dir)?;
        NativeBackend::from_manifest(&manifest, config, max_batch)
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Set the per-call worker-thread budget (`0` = auto: `CLO_HDNN_THREADS`
    /// when set, else all cores) — the inherent twin of
    /// [`HdBackend::set_parallelism`].
    pub fn set_threads(&mut self, threads: usize) {
        self.pool = WorkerPool::new(threads);
    }

    /// The current per-call thread budget.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Select the encode kernel (`--encode scalar|signgemm` — both are
    /// bit-exact; `scalar` is the ablation/parity baseline).
    pub fn set_encode_kernel(&mut self, kernel: crate::hdc::EncodeKernel) {
        self.inner.set_kernel(kernel);
    }

    /// The encode kernel currently serving traffic.
    pub fn encode_kernel(&self) -> crate::hdc::EncodeKernel {
        self.inner.kernel()
    }

    /// The pool handed to sharded kernels: `None` when serial (so the
    /// kernels take their inline path with zero scope overhead).
    fn pool_opt(&self) -> Option<&WorkerPool> {
        if self.pool.is_serial() {
            None
        } else {
            Some(&self.pool)
        }
    }

    /// Recalibrate `scale_q` from representative (already feature-quantized)
    /// inputs — the Rust twin of the build-time calibration; synthetic
    /// configs should call this before training.
    pub fn calibrate(&mut self, xs: &[f32], batch: usize) {
        self.inner.calibrate(xs, batch);
    }

    /// The empty-batch / over-batch guard shared with `PjrtBackend::pad`.
    fn check_batch(&self, what: &str, batch: usize) -> Result<()> {
        if batch == 0 {
            bail!("{what}: empty batch (batch must be >= 1)");
        }
        if batch > self.max_batch {
            bail!("{what}: batch {batch} exceeds max_batch {}", self.max_batch);
        }
        Ok(())
    }
}

impl HdBackend for NativeBackend {
    fn cfg(&self) -> &HdConfig {
        self.inner.cfg()
    }

    fn encode_segment(&mut self, xs: &[f32], batch: usize, seg: usize) -> Result<Vec<f32>> {
        self.check_batch("encode_segment", batch)?;
        self.inner.encode_segment(xs, batch, seg)
    }

    fn encode_full(&mut self, xs: &[f32], batch: usize) -> Result<Vec<f32>> {
        self.check_batch("encode_full", batch)?;
        if batch > 1 {
            // the batched engine: rows sharded over the worker pool (or run
            // inline when serial); bit-identical to the per-sample loop
            self.inner.encode_qhvs(xs, batch, self.pool_opt())
        } else {
            self.inner.encode_full(xs, batch)
        }
    }

    fn encode_segment_packed(&mut self, xs: &[f32], batch: usize, seg: usize) -> Result<Vec<u64>> {
        // fused quantize-and-pack (zero repacking between encode and the
        // XOR-tree search); bits identical to the trait's encode+pack default
        self.check_batch("encode_segment_packed", batch)?;
        self.inner.encode_segment_packed(xs, batch, seg)
    }

    fn search(
        &mut self,
        qs: &[f32],
        batch: usize,
        chvs: &[f32],
        classes: usize,
        len: usize,
    ) -> Result<Vec<f32>> {
        self.check_batch("search", batch)?;
        self.inner.search(qs, batch, chvs, classes, len)
    }

    fn search_packed(
        &mut self,
        qs: &[u64],
        batch: usize,
        chvs: &[u64],
        classes: usize,
        len: usize,
    ) -> Result<Vec<f32>> {
        // the XOR+popcount fast path (the trait default unpacks and runs
        // the scalar L1 kernel; both yield identical distances), sharded
        // over AM class row-blocks when the pool has threads to spend
        self.check_batch("search_packed", batch)?;
        packed::hamming_search_pool(&self.pool, qs, batch, chvs, classes, len)
    }

    fn set_parallelism(&mut self, threads: usize) {
        self.set_threads(threads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tiny() -> HdConfig {
        HdConfig::synthetic("t", 8, 8, 32, 32, 8, 4)
    }

    #[test]
    fn matches_software_encoder_exactly() {
        let cfg = tiny();
        let mut native = NativeBackend::seeded(cfg.clone(), 11, 4).unwrap();
        let mut sw = SoftwareEncoder::random(cfg.clone(), 11);
        let mut rng = Rng::new(12);
        let xs: Vec<f32> = (0..3 * cfg.features()).map(|_| rng.range(-90, 91) as f32).collect();
        assert_eq!(
            native.encode_full(&xs, 3).unwrap(),
            sw.encode_full(&xs, 3).unwrap()
        );
        for s in 0..cfg.segments {
            assert_eq!(
                native.encode_segment(&xs, 3, s).unwrap(),
                sw.encode_segment(&xs, 3, s).unwrap(),
                "segment {s}"
            );
        }
    }

    #[test]
    fn rejects_empty_and_oversized_batches() {
        let cfg = tiny();
        let mut native = NativeBackend::seeded(cfg.clone(), 1, 2).unwrap();
        assert!(native.encode_full(&[], 0).is_err());
        assert!(native.encode_segment(&[], 0, 0).is_err());
        assert!(native.search(&[], 0, &[], cfg.classes, cfg.seg_len()).is_err());
        let xs = vec![0.0; 3 * cfg.features()];
        assert!(native.encode_full(&xs, 3).is_err());
        assert!(NativeBackend::seeded(cfg, 1, 0).is_err());
    }

    #[test]
    fn packed_search_matches_fallback_and_scalar_l1() {
        use crate::hdc::packed;
        let cfg = tiny();
        let mut native = NativeBackend::seeded(cfg.clone(), 4, 2).unwrap();
        // the SoftwareEncoder keeps the trait's unpack-fallback default
        let mut sw = SoftwareEncoder::random(cfg.clone(), 4);
        let mut rng = Rng::new(5);
        let len = cfg.seg_len();
        let q: Vec<f32> = (0..len).map(|_| rng.sign()).collect();
        let chv: Vec<f32> = (0..cfg.classes * len).map(|_| rng.sign()).collect();
        let qp = packed::pack_signs(&q);
        let cp = packed::pack_rows(&chv, cfg.classes, len).unwrap();
        let fast = native.search_packed(&qp, 1, &cp, cfg.classes, len).unwrap();
        let fallback = sw.search_packed(&qp, 1, &cp, cfg.classes, len).unwrap();
        let scalar = crate::hdc::distance::l1_batch(&q, 1, &chv, cfg.classes, len).unwrap();
        assert_eq!(fast, fallback);
        assert_eq!(fast, scalar);
    }

    #[test]
    fn packed_search_rejects_empty_and_oversized_batches() {
        let cfg = tiny();
        let mut native = NativeBackend::seeded(cfg.clone(), 4, 2).unwrap();
        assert!(native
            .search_packed(&[], 0, &[], cfg.classes, cfg.seg_len())
            .is_err());
        let w = crate::hdc::packed::words_for(cfg.seg_len());
        let qs = vec![0u64; 3 * w];
        let cs = vec![0u64; cfg.classes * w];
        assert!(native
            .search_packed(&qs, 3, &cs, cfg.classes, cfg.seg_len())
            .is_err());
    }

    #[test]
    fn threaded_backend_is_bit_identical_to_serial() {
        let cfg = tiny();
        let mut serial = NativeBackend::seeded(cfg.clone(), 21, 8).unwrap();
        serial.set_threads(1);
        let mut pooled = NativeBackend::seeded(cfg.clone(), 21, 8).unwrap();
        pooled.set_threads(4);
        assert_eq!(pooled.threads(), 4);
        let mut rng = Rng::new(22);
        let xs: Vec<f32> =
            (0..7 * cfg.features()).map(|_| rng.range(-90, 91) as f32).collect();
        assert_eq!(
            serial.encode_full(&xs, 7).unwrap(),
            pooled.encode_full(&xs, 7).unwrap()
        );
        let len = cfg.seg_len();
        let q_pm1: Vec<f32> = (0..len).map(|_| rng.sign()).collect();
        let c_pm1: Vec<f32> = (0..cfg.classes * len).map(|_| rng.sign()).collect();
        let q = crate::hdc::packed::pack_signs(&q_pm1);
        let chvs = crate::hdc::packed::pack_rows(&c_pm1, cfg.classes, len).unwrap();
        assert_eq!(
            serial.search_packed(&q, 1, &chvs, cfg.classes, len).unwrap(),
            pooled.search_packed(&q, 1, &chvs, cfg.classes, len).unwrap()
        );
    }

    #[test]
    fn encode_segment_packed_matches_trait_default_and_guards_batch() {
        let cfg = tiny();
        let mut native = NativeBackend::seeded(cfg.clone(), 14, 4).unwrap();
        let mut sw = SoftwareEncoder::random(cfg.clone(), 14);
        let mut rng = Rng::new(15);
        let xs: Vec<f32> =
            (0..2 * cfg.features()).map(|_| rng.range(-90, 91) as f32).collect();
        for s in 0..cfg.segments {
            let fast = native.encode_segment_packed(&xs, 2, s).unwrap();
            // SoftwareEncoder overrides too; rebuild the default from parts
            let q = sw.encode_segment(&xs, 2, s).unwrap();
            let want = crate::hdc::packed::pack_rows(&q, 2, cfg.seg_len()).unwrap();
            assert_eq!(fast, want, "segment {s}");
        }
        assert!(native.encode_segment_packed(&xs, 0, 0).is_err());
        assert!(native.encode_segment_packed(&xs, 9, 0).is_err());
    }

    #[test]
    fn search_is_l1() {
        let cfg = tiny();
        let mut native = NativeBackend::seeded(cfg.clone(), 2, 1).unwrap();
        let mut rng = Rng::new(3);
        let len = cfg.seg_len();
        let q: Vec<f32> = (0..len).map(|_| rng.range(-127, 128) as f32).collect();
        let chv: Vec<f32> = (0..cfg.classes * len)
            .map(|_| rng.range(-127, 128) as f32)
            .collect();
        assert_eq!(
            native.search(&q, 1, &chv, cfg.classes, len).unwrap(),
            crate::hdc::distance::l1_batch(&q, 1, &chv, cfg.classes, len).unwrap()
        );
    }
}
