//! Parsed form of `artifacts/manifest.json` (written by aot.py): the
//! catalogue of AOT-lowered executables, datasets, weights, and per-config
//! calibration the Rust side runs against.

use crate::config::HdConfig;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One lowered executable's metadata.
#[derive(Clone, Debug)]
pub struct ExeMeta {
    pub name: String,
    pub file: String,
    pub config: String,
    pub kind: String,
    pub batch: usize,
    /// input shapes as lowered (row-major dims)
    pub inputs: Vec<Vec<usize>>,
    /// output shape
    pub out: Vec<usize>,
}

/// One dataset artifact's metadata.
#[derive(Clone, Debug)]
pub struct DatasetMeta {
    pub name: String,
    pub file: String,
    pub n: usize,
    pub dim: usize,
    pub classes: usize,
}

/// WCFE build info (normal mode only).
#[derive(Clone, Debug)]
pub struct WcfeMeta {
    pub image_hw: usize,
    pub image_c: usize,
    pub channels: Vec<usize>,
    pub fc_out: usize,
    pub clusters: usize,
    pub pretrain_acc: f64,
    pub clustered_acc: f64,
    pub weights: String,
    pub weights_dense: String,
    pub codebook: String,
}

/// Durable knowledge-store wiring: where the serving layer checkpoints the
/// learned class hypervectors for a config (see `crate::hdc::knowledge`
/// for the CLOK file format).
#[derive(Clone, Debug)]
pub struct KnowledgeMeta {
    /// checkpoint file, relative to the artifact dir
    pub file: String,
    /// which manifest config the checkpoint belongs to
    pub config: String,
    /// auto-snapshot cadence (every N learns; 0 = explicit snapshots only)
    pub every_learns: usize,
}

/// One `models` entry: a named serving model for the multi-model registry
/// (`clo_hdnn serve --listen` hosts every entry side by side).
///
/// ```json
/// "models": [
///   {"name": "tiny", "config": "tiny",
///    "knowledge": "knowledge_tiny.clok", "every_learns": 256,
///    "search": "packed", "threads": 0, "tau": 0.5,
///    "policy": "confidence:40"}
/// ]
/// ```
#[derive(Clone, Debug)]
pub struct ModelMeta {
    /// registry name — what wire-v2 frames address (defaults to `config`)
    pub name: String,
    /// the manifest config this model serves (defaults to `name`)
    pub config: String,
    /// default search kernel ("l1"|"packed"; absent = library default)
    pub search: Option<String>,
    /// per-model worker-thread budget (0 = auto)
    pub threads: usize,
    /// progressive-search confidence override
    pub tau: Option<f64>,
    /// dual-mode routing policy spelling
    /// (`auto`|`bypass`|`normal`|`confidence:<margin>`; absent = auto) —
    /// parsed by `ModePolicy::parse` when the model is served
    pub policy: Option<String>,
    /// knowledge checkpoint file, relative to the artifact dir
    pub knowledge_file: Option<String>,
    /// auto-snapshot cadence (every N learns; 0 = explicit snapshots only)
    pub every_learns: usize,
}

/// Parsed form of `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// the artifact directory the manifest lives in
    pub dir: PathBuf,
    /// per-config HD geometry + calibration
    pub configs: BTreeMap<String, HdConfig>,
    /// AOT-lowered executables by name
    pub executables: BTreeMap<String, ExeMeta>,
    /// dataset artifacts by name
    pub datasets: BTreeMap<String, DatasetMeta>,
    /// WCFE build info (normal mode only)
    pub wcfe: Option<WcfeMeta>,
    /// single-model knowledge wiring (predates `models`; still honored by
    /// the single-model serve path)
    pub knowledge: Option<KnowledgeMeta>,
    /// multi-model registry entries (empty when absent)
    pub models: Vec<ModelMeta>,
}

fn usize_arr(j: &Json) -> Vec<usize> {
    j.as_arr()
        .map(|a| a.iter().filter_map(Json::as_usize).collect())
        .unwrap_or_default()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;

        let mut configs = BTreeMap::new();
        for (name, meta) in j
            .get("configs")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing configs"))?
        {
            configs.insert(name.clone(), HdConfig::from_manifest(name, meta)?);
        }

        let mut executables = BTreeMap::new();
        for e in j
            .get("executables")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing executables"))?
        {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("executable missing name"))?
                .to_string();
            let inputs = e
                .get("inputs")
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .map(|i| usize_arr(i.get("shape").unwrap_or(&Json::Null)))
                        .collect()
                })
                .unwrap_or_default();
            executables.insert(
                name.clone(),
                ExeMeta {
                    file: e
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("{name}: missing file"))?
                        .to_string(),
                    config: e
                        .get("config")
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string(),
                    kind: e.get("kind").and_then(Json::as_str).unwrap_or("").to_string(),
                    batch: e.get("batch").and_then(Json::as_usize).unwrap_or(1),
                    inputs,
                    out: usize_arr(e.get("out").unwrap_or(&Json::Null)),
                    name,
                },
            );
        }

        let mut datasets = BTreeMap::new();
        for d in j.get("datasets").and_then(Json::as_arr).unwrap_or(&[]) {
            let name = d
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("dataset missing name"))?
                .to_string();
            datasets.insert(
                name.clone(),
                DatasetMeta {
                    file: d
                        .get("file")
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string(),
                    n: d.get("n").and_then(Json::as_usize).unwrap_or(0),
                    dim: d.get("dim").and_then(Json::as_usize).unwrap_or(0),
                    classes: d.get("classes").and_then(Json::as_usize).unwrap_or(0),
                    name,
                },
            );
        }

        let wcfe = j.get("wcfe").map(|w| WcfeMeta {
            image_hw: w.get("image_hw").and_then(Json::as_usize).unwrap_or(32),
            image_c: w.get("image_c").and_then(Json::as_usize).unwrap_or(3),
            channels: usize_arr(w.get("channels").unwrap_or(&Json::Null)),
            fc_out: w.get("fc_out").and_then(Json::as_usize).unwrap_or(0),
            clusters: w.get("clusters").and_then(Json::as_usize).unwrap_or(16),
            pretrain_acc: w.get("pretrain_acc").and_then(Json::as_f64).unwrap_or(0.0),
            clustered_acc: w.get("clustered_acc").and_then(Json::as_f64).unwrap_or(0.0),
            weights: w.get("weights").and_then(Json::as_str).unwrap_or("").to_string(),
            weights_dense: w
                .get("weights_dense")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            codebook: w.get("codebook").and_then(Json::as_str).unwrap_or("").to_string(),
        });

        let knowledge = j.get("knowledge").map(|k| KnowledgeMeta {
            file: k
                .get("file")
                .and_then(Json::as_str)
                .unwrap_or("knowledge.clok")
                .to_string(),
            config: k.get("config").and_then(Json::as_str).unwrap_or("").to_string(),
            every_learns: k.get("every_learns").and_then(Json::as_usize).unwrap_or(0),
        });

        let mut models = Vec::new();
        for m in j.get("models").and_then(Json::as_arr).unwrap_or(&[]) {
            let config = m.get("config").and_then(Json::as_str).unwrap_or("").to_string();
            let name = m
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or(config.as_str())
                .to_string();
            if name.is_empty() {
                bail!("manifest models entry needs a name or a config");
            }
            let config = if config.is_empty() { name.clone() } else { config };
            if !configs.contains_key(&config) {
                bail!("manifest model '{name}' references unknown config '{config}'");
            }
            if models.iter().any(|e: &ModelMeta| e.name == name) {
                bail!("manifest models entry '{name}' is duplicated");
            }
            models.push(ModelMeta {
                search: m.get("search").and_then(Json::as_str).map(str::to_string),
                threads: m.get("threads").and_then(Json::as_usize).unwrap_or(0),
                tau: m.get("tau").and_then(Json::as_f64),
                policy: m.get("policy").and_then(Json::as_str).map(str::to_string),
                knowledge_file: m
                    .get("knowledge")
                    .and_then(Json::as_str)
                    .map(str::to_string),
                every_learns: m.get("every_learns").and_then(Json::as_usize).unwrap_or(0),
                name,
                config,
            });
        }

        Ok(Manifest { dir, configs, executables, datasets, wcfe, knowledge, models })
    }

    /// The registry entry for `name`, when the manifest declares one.
    pub fn model(&self, name: &str) -> Option<&ModelMeta> {
        self.models.iter().find(|m| m.name == name)
    }

    /// Absolute path of a registry model's knowledge checkpoint, when its
    /// manifest entry wires one up.
    pub fn model_knowledge_path(&self, name: &str) -> Option<PathBuf> {
        self.model(name)
            .and_then(|m| m.knowledge_file.as_ref())
            .map(|f| self.dir.join(f))
    }

    /// Absolute path of the knowledge checkpoint for `config`, when the
    /// manifest wires one up for it.
    pub fn knowledge_path(&self, config: &str) -> Option<PathBuf> {
        self.knowledge
            .as_ref()
            .filter(|k| k.config == config)
            .map(|k| self.dir.join(&k.file))
    }

    pub fn config(&self, name: &str) -> Result<&HdConfig> {
        self.configs
            .get(name)
            .ok_or_else(|| anyhow!("no config {name} in manifest"))
    }

    pub fn executable(&self, name: &str) -> Result<&ExeMeta> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow!("no executable {name} in manifest"))
    }

    pub fn dataset(&self, name: &str) -> Result<&DatasetMeta> {
        self.datasets
            .get(name)
            .ok_or_else(|| anyhow!("no dataset {name} in manifest"))
    }

    pub fn dataset_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.dataset(name)?.file))
    }

    pub fn exe_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.executable(name)?.file))
    }

    /// Default artifact directory: $CLO_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("CLO_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Validate that every referenced file exists on disk.
    pub fn check_files(&self) -> Result<()> {
        for e in self.executables.values() {
            let p = self.dir.join(&e.file);
            if !p.exists() {
                bail!("missing artifact {}", p.display());
            }
        }
        for d in self.datasets.values() {
            let p = self.dir.join(&d.file);
            if !p.exists() {
                bail!("missing dataset {}", p.display());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    const SAMPLE: &str = r#"{
      "version": 1,
      "configs": {"tiny": {"f1":8,"f2":8,"d1":32,"d2":32,"segments":8,
        "classes":10,"qbits":8,"scale_x":0.5,"scale_q":3.0,
        "mean_absdiff":40.0,"batches":[1,8],"image":false}},
      "executables": [
        {"name":"encode_full_tiny_b1","file":"e.hlo.txt","config":"tiny",
         "kind":"encode_full","batch":1,
         "inputs":[{"shape":[1,64],"dtype":"float32"}],"out":[1,1024]}],
      "datasets": [{"name":"ds_tiny_train","file":"d.bin","n":400,
                    "dim":64,"classes":10}],
      "knowledge": {"file":"knowledge_tiny.clok","config":"tiny",
                    "every_learns":256},
      "models": [
        {"name":"tiny","knowledge":"knowledge_tiny.clok","every_learns":128,
         "search":"packed","threads":2,"tau":0.25,"policy":"confidence:40"},
        {"name":"tiny-l1","config":"tiny"}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let dir = std::env::temp_dir().join("clo_hdnn_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(SAMPLE.as_bytes()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let cfg = m.config("tiny").unwrap();
        assert_eq!(cfg.dim(), 1024);
        let e = m.executable("encode_full_tiny_b1").unwrap();
        assert_eq!(e.inputs, vec![vec![1, 64]]);
        assert_eq!(e.out, vec![1, 1024]);
        assert_eq!(m.dataset("ds_tiny_train").unwrap().n, 400);
        assert!(m.config("absent").is_err());
        // knowledge section: checkpoint path resolves per config
        let k = m.knowledge.as_ref().unwrap();
        assert_eq!(k.every_learns, 256);
        assert_eq!(
            m.knowledge_path("tiny").unwrap(),
            m.dir.join("knowledge_tiny.clok")
        );
        assert!(m.knowledge_path("other").is_none());
        // models section: registry entries with defaults and overrides
        assert_eq!(m.models.len(), 2);
        let tiny = m.model("tiny").unwrap();
        assert_eq!(tiny.config, "tiny");
        assert_eq!(tiny.search.as_deref(), Some("packed"));
        assert_eq!(tiny.threads, 2);
        assert_eq!(tiny.tau, Some(0.25));
        assert_eq!(tiny.policy.as_deref(), Some("confidence:40"));
        assert_eq!(tiny.every_learns, 128);
        assert_eq!(
            m.model_knowledge_path("tiny").unwrap(),
            m.dir.join("knowledge_tiny.clok")
        );
        let l1 = m.model("tiny-l1").unwrap();
        assert_eq!(l1.config, "tiny", "two registry names may share one config");
        assert!(l1.search.is_none());
        assert!(l1.policy.is_none());
        assert_eq!(l1.threads, 0);
        assert!(m.model_knowledge_path("tiny-l1").is_none());
        assert!(m.model("absent").is_none());
        // files don't exist -> check_files errors
        assert!(m.check_files().is_err());
    }

    #[test]
    fn models_entries_are_validated() {
        let dir = std::env::temp_dir().join("clo_hdnn_manifest_models_bad");
        std::fs::create_dir_all(&dir).unwrap();
        // a model naming an unknown config must fail the load
        let bad = SAMPLE.replace(r#"{"name":"tiny-l1","config":"tiny"}"#,
                                 r#"{"name":"tiny-l1","config":"missing"}"#);
        std::fs::write(dir.join("manifest.json"), bad).unwrap();
        let e = Manifest::load(&dir).unwrap_err().to_string();
        assert!(e.contains("missing"), "{e}");
        // duplicate names must fail the load
        let dup = SAMPLE.replace(r#"{"name":"tiny-l1","config":"tiny"}"#,
                                 r#"{"name":"tiny","config":"tiny"}"#);
        std::fs::write(dir.join("manifest.json"), dup).unwrap();
        let e = Manifest::load(&dir).unwrap_err().to_string();
        assert!(e.contains("duplicated"), "{e}");
    }
}
