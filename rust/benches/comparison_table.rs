//! Fig.11 — chip summary + SOTA comparison table. The competitor rows are
//! the published constants from the paper's table; our row comes from the
//! calibrated model. Prints the headline ratios: 1.73-7.77x (FE) and
//! 4.85x (classifier) higher energy efficiency.

use clo_hdnn::config::ChipConfig;
use clo_hdnn::energy::report::{comparison_table, sota_rows};
use clo_hdnn::energy::EnergyModel;
use clo_hdnn::util::stats::Table;

fn main() {
    let chip = ChipConfig::default();
    let model = EnergyModel::default();

    println!("== Fig.11 chip summary (this reproduction's model envelope) ==");
    let mut s = Table::new(&["field", "value"]);
    for (k, v) in [
        ("Technology", format!("{} nm CMOS (modeled)", chip.technology_nm)),
        ("Die size", format!("{} mm^2", chip.die_area_mm2)),
        ("SRAM", format!("{} KB (WCFE) + {} KB (HDC)", chip.sram_wcfe_kb, chip.sram_hdc_kb)),
        ("Supply", format!("{}-{} V", chip.vmin, chip.vmax)),
        ("Frequency", format!("{}-{} MHz", chip.fmin_mhz, chip.fmax_mhz)),
        ("Model", "CNN (WCFE) + HDC".to_string()),
        ("Precision", "BF16 (CNN), INT1-8 (HDC inf), INT8 (HDC train)".to_string()),
        ("Feature dim F", "8-1024".to_string()),
        ("HDC dim D", "1024-8192".to_string()),
        ("Max classes", format!("{}", chip.max_classes)),
        (
            "Peak EE",
            format!(
                "WCFE {:.2}-{:.2} TFLOPS/W, HDC {:.2}-{:.2} TOPS/W",
                model.efficiency(clo_hdnn::energy::Domain::Wcfe, 1.2),
                model.efficiency(clo_hdnn::energy::Domain::Wcfe, 0.7),
                model.efficiency(clo_hdnn::energy::Domain::Hdc, 1.2),
                model.efficiency(clo_hdnn::energy::Domain::Hdc, 0.7),
            ),
        ),
    ] {
        s.row(&[k.to_string(), v]);
    }
    s.print();

    println!("\n== Fig.11 SOTA comparison (EE scaled to 40 nm, as in the paper) ==");
    let (ours, rows, ratios) = comparison_table(&model);
    let mut t = Table::new(&[
        "chip", "tech", "learning", "design", "encoder", "precision",
        "mem (KB)", "area (mm^2)", "EE CNN (TFLOPS/W)", "EE clf (TOPS/W)",
    ]);
    for r in std::iter::once(&ours).chain(rows.iter()) {
        t.row(&[
            r.name.to_string(),
            format!("{} nm", r.technology_nm),
            r.learning_mode.into(),
            r.design.into(),
            r.encoder.into(),
            r.precision.into(),
            format!("{}", r.on_chip_mem_kb),
            format!("{}", r.area_mm2),
            r.ee_cnn.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
            r.ee_classifier.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into()),
        ]);
    }
    t.print();

    println!("\nheadline ratios:");
    println!(
        "  FE energy efficiency vs ESSERC'24 [4]: {:.2}x   (paper: 1.73x)",
        ratios.fe_vs_hdc_sota
    );
    println!(
        "  FE energy efficiency vs VLSI'23  [8]: {:.2}x   (paper: 7.77x)",
        ratios.fe_vs_cim_sota
    );
    println!(
        "  classifier EE        vs ESSERC'24 [4]: {:.2}x   (paper: 4.85x)",
        ratios.classifier_vs_sota
    );
    println!(
        "  first chip in the table supporting end-to-end CONTINUAL learning for HDC: {}",
        sota_rows().iter().all(|r| r.learning_mode != "CL HDC")
    );
}
