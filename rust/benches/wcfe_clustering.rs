//! Fig.7 — WCFE weight clustering: parameter reduction (paper: 1.9x),
//! CONV-computation reduction (paper: 2.1x), PE-array cycles, and a
//! codebook-size ablation. Uses the real pretrained+clustered weights from
//! `make artifacts` when available, otherwise a random-weight twin.

use clo_hdnn::config::ChipConfig;
use clo_hdnn::data::TensorFile;
use clo_hdnn::runtime::Manifest;
use clo_hdnn::util::stats::Table;
use clo_hdnn::util::Rng;
use clo_hdnn::wcfe::codebook::LayerCodebook;
use clo_hdnn::wcfe::pe_array::{LayerGeometry, PeArray};
use clo_hdnn::wcfe::schedule::ReuseSchedule;
use clo_hdnn::wcfe::Codebook;

struct Layer {
    name: String,
    w: Vec<f32>,
    k_in: usize,
    c_out: usize,
    geo: LayerGeometry,
}

fn load_layers() -> Vec<Layer> {
    let geos = [(32usize, 32usize), (16, 16), (8, 8)];
    if let Ok(m) = Manifest::load(Manifest::default_dir()) {
        if let Some(w) = &m.wcfe {
            if let Ok(tf) = TensorFile::load(m.dir.join(&w.weights_dense)) {
                let mut out = Vec::new();
                let mut c_in = w.image_c;
                for (i, &c_out) in w.channels.iter().enumerate() {
                    let name = format!("conv{}", i + 1);
                    let t = tf.f32(&name).unwrap().to_vec();
                    out.push(Layer {
                        name,
                        w: t,
                        k_in: 9 * c_in,
                        c_out,
                        geo: LayerGeometry { out_h: geos[i].0, out_w: geos[i].1 },
                    });
                    c_in = c_out;
                }
                println!("(using pretrained WCFE weights from artifacts/)");
                return out;
            }
        }
    }
    println!("(artifacts missing — using random-weight twin)");
    let mut rng = Rng::new(1);
    let chans = [(3usize, 32usize), (32, 64), (64, 128)];
    chans
        .iter()
        .enumerate()
        .map(|(i, &(ci, co))| Layer {
            name: format!("conv{}", i + 1),
            w: (0..9 * ci * co).map(|_| rng.normal_f32() * 0.1).collect(),
            k_in: 9 * ci,
            c_out: co,
            geo: LayerGeometry { out_h: geos[i].0, out_w: geos[i].1 },
        })
        .collect()
}

fn main() {
    let layers = load_layers();
    let pe = PeArray::new(ChipConfig::default());
    let clusters = 16;

    println!("\n== Fig.7: per-layer pattern-reuse costs (codebook = {clusters}) ==");
    let mut table = Table::new(&[
        "layer", "K(in)", "Cout", "dense MACs", "clustered mults", "adds",
        "cycle reduction", "compute reduction",
    ]);
    let mut cbs = Vec::new();
    let (mut dense_slots, mut clus_slots) = (0.0f64, 0.0f64);
    for l in &layers {
        let cb = LayerCodebook::from_weights(&l.name, &l.w, l.k_in, l.c_out, clusters);
        let sched = ReuseSchedule::build(&cb);
        let d = pe.dense_cost(&sched, l.geo);
        let c = pe.clustered_cost(&sched, l.geo);
        let red = pe.compute_reduction(&sched, l.geo);
        dense_slots += 1.2 * d.mults as f64 + d.adds as f64;
        clus_slots += 1.2 * c.mults as f64 + c.adds as f64;
        table.row(&[
            l.name.clone(),
            format!("{}", l.k_in),
            format!("{}", l.c_out),
            format!("{}", d.mults),
            format!("{}", c.mults),
            format!("{}", c.adds),
            format!("{:.2}x", d.cycles as f64 / c.cycles.max(1) as f64),
            format!("{:.2}x", red),
        ]);
        cbs.push(cb);
    }
    table.print();
    println!(
        "network CONV-compute reduction: {:.2}x (paper Fig.7: 2.1x)",
        dense_slots / clus_slots
    );

    // parameter reduction including the dense FC tail (paper: 1.9x)
    let fc_params = 128 * 512u64;
    let codebook = Codebook { layers: cbs, dense_tail_bits: fc_params * 16 };
    println!(
        "parameter reduction: {:.2}x — {} -> {} KiB (paper Fig.7: 1.9x)",
        codebook.param_reduction(),
        codebook.total_dense_bits() / 8 / 1024,
        codebook.total_clustered_bits() / 8 / 1024
    );

    // codebook-size ablation: fidelity vs compression (DESIGN.md ablation)
    println!("\n== ablation: codebook size vs fidelity and reduction ==");
    let mut t2 = Table::new(&[
        "clusters", "rel L1 err (conv3)", "param reduction", "compute reduction",
    ]);
    let l3 = &layers[2];
    for &k in &[2usize, 4, 8, 16, 32, 64] {
        let cb = LayerCodebook::from_weights(&l3.name, &l3.w, l3.k_in, l3.c_out, k);
        let err = clo_hdnn::wcfe::clustering::relative_l1_error(
            &l3.w, &cb.centroids, &cb.idx);
        let sched = ReuseSchedule::build(&cb);
        let red = pe.compute_reduction(&sched, l3.geo);
        let full = Codebook {
            layers: layers
                .iter()
                .map(|l| LayerCodebook::from_weights(&l.name, &l.w, l.k_in, l.c_out, k))
                .collect(),
            dense_tail_bits: fc_params * 16,
        };
        t2.row(&[
            format!("{k}"),
            format!("{err:.4}"),
            format!("{:.2}x", full.param_reduction()),
            format!("{red:.2}x"),
        ]);
    }
    t2.print();
    println!("(the chip's 16-entry codebook is the knee: <10% weight error, ~1.9x params, ~2.1x compute)");
}
