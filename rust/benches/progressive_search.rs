//! Fig.4 / Fig.6 — progressive search: complexity reduction vs accuracy
//! across the confidence-threshold sweep, plus the cache-residency story
//! (only partial CHVs fetched) and measured wall-clock speedup.
//!
//! Paper claim: up to 61% complexity reduction with negligible accuracy
//! loss. Runs on the software backend (numerically identical to the AOT
//! kernels, pinned by artifacts/golden.bin).

use clo_hdnn::config::HdConfig;
use clo_hdnn::data::Dataset;
use clo_hdnn::hdc::encoder::SoftwareEncoder;
use clo_hdnn::hdc::HdBackend;
use clo_hdnn::hdc::{HdClassifier, ProgressiveSearch, Trainer};
use clo_hdnn::util::stats::{fmt_secs, Table};
use clo_hdnn::util::Rng;

fn blobs(classes: usize, per: usize, feat: usize, noise: f32, seed: u64) -> Dataset {
    // class prototypes come from a FIXED seed so train/test splits share
    // the same class geometry; `seed` only drives the sample noise
    let mut prng = Rng::new(0xB10B);
    let protos: Vec<Vec<f32>> = (0..classes)
        .map(|_| (0..feat).map(|_| prng.normal_f32() * 40.0).collect())
        .collect();
    let mut rng = Rng::new(seed);
    let mut x = Vec::new();
    let mut y = Vec::new();
    for c in 0..classes {
        for _ in 0..per {
            x.extend(protos[c].iter().map(|&v| v + rng.normal_f32() * noise));
            y.push(c as u16);
        }
    }
    Dataset::from_parts(x, y, feat, classes).unwrap()
}

/// Build a software encoder with build-time-style scale calibration (the
/// AOT artifacts carry the python-calibrated scale; synthetic configs must
/// calibrate here or QHVs saturate).
fn calibrated_encoder(cfg: &HdConfig, seed: u64, train: &Dataset) -> SoftwareEncoder {
    let mut enc = SoftwareEncoder::random(cfg.clone(), seed);
    let n = train.n.min(64);
    let sample: Vec<f32> = (0..n)
        .flat_map(|i| clo_hdnn::hdc::quantize::quantize_features(train.sample(i), cfg.scale_x))
        .collect();
    enc.calibrate(&sample, n);
    enc
}

fn main() {
    let cfg = HdConfig::synthetic("fig4", 32, 20, 64, 32, 16, 26);
    let train = blobs(26, 40, cfg.features(), 34.0, 1);
    let test = blobs(26, 15, cfg.features(), 34.0, 2);

    // train once, snapshot the CHV store, reuse across thresholds
    let enc0 = calibrated_encoder(&cfg, 3, &train);
    let cfg = enc0.cfg().clone();
    let mut base = HdClassifier::new(
        Box::new(enc0),
        ProgressiveSearch { tau: f32::INFINITY, min_segments: usize::MAX, ..Default::default() },
    );
    Trainer { retrain_epochs: 1 }.train_all(&mut base, &train).unwrap();
    let store = base.store.clone();

    println!("== Fig.4: progressive-search threshold sweep (D={}, {} segments, {} classes) ==",
             cfg.dim(), cfg.segments, cfg.classes);
    let mut table = Table::new(&[
        "tau", "accuracy", "mean segs", "complexity saved", "CHV cache fetched",
        "time/inference", "early exits",
    ]);
    let mut full_acc = 0.0;
    for &tau in &[f32::INFINITY, 2.0, 1.0, 0.5, 0.25, 0.12, 0.06, 0.03] {
        let mut cl = HdClassifier::new(
            Box::new(calibrated_encoder(&cfg, 3, &train)),
            ProgressiveSearch { tau, min_segments: 1, ..Default::default() },
        );
        cl.store = store.clone();
        let t0 = std::time::Instant::now();
        let report = cl
            .evaluate((0..test.n).map(|i| (test.sample(i).to_vec(), test.label(i))))
            .unwrap();
        let dt = t0.elapsed().as_secs_f64() / test.n as f64;
        if tau.is_infinite() {
            full_acc = report.accuracy;
        }
        table.row(&[
            if tau.is_infinite() { "inf (exhaustive)".into() } else { format!("{tau}") },
            format!("{:.4}", report.accuracy),
            format!("{:.2}/{}", report.mean_segments, cfg.segments),
            format!("{:.1}%", report.complexity_reduction() * 100.0),
            format!(
                "{} / {} KiB",
                cl.store.bytes_resident(report.mean_segments.ceil() as usize) / 1024,
                cl.store.bytes_total() / 1024
            ),
            fmt_secs(dt),
            format!("{:.0}%", report.early_exit_rate * 100.0),
        ]);
    }
    table.print();
    println!(
        "paper Fig.4: up to 61% complexity reduction with negligible accuracy loss \
         (exhaustive baseline here: {full_acc:.4})"
    );

    // per-dataset operating point (the tau the examples use)
    println!("\n== operating point tau=0.5 across dataset geometries ==");
    let mut t2 = Table::new(&["geometry", "accuracy", "acc delta vs full", "complexity saved"]);
    for (name, classes, noise) in [("isolet-like", 26, 18.0), ("ucihar-like", 6, 22.0), ("easy", 10, 8.0)] {
        let cfg = HdConfig::synthetic(name, 32, 20, 64, 32, 16, classes);
        let train = blobs(classes, 40, cfg.features(), noise, 7);
        let test = blobs(classes, 20, cfg.features(), noise, 8);
        let mk = |tau: f32, min_seg: usize| {
            let mut cl = HdClassifier::new(
                Box::new(calibrated_encoder(&cfg, 9, &train)),
                ProgressiveSearch { tau, min_segments: min_seg, ..Default::default() },
            );
            Trainer { retrain_epochs: 1 }.train_all(&mut cl, &train).unwrap();
            cl.evaluate((0..test.n).map(|i| (test.sample(i).to_vec(), test.label(i))))
                .unwrap()
        };
        let full = mk(f32::INFINITY, usize::MAX);
        let prog = mk(0.5, 1);
        t2.row(&[
            name.into(),
            format!("{:.4}", prog.accuracy),
            format!("{:+.4}", prog.accuracy - full.accuracy),
            format!("{:.1}%", prog.complexity_reduction() * 100.0),
        ]);
    }
    t2.print();
}
