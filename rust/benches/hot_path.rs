//! §Perf — L3 hot-path microbenchmarks: per-stage latency of the serving
//! loop (quantize / encode-segment / partial-search / full pipeline)
//! through the NativeBackend, plus the dynamic batcher's b8 amortization.
//! With `--features pjrt` and a populated artifacts/ directory the same
//! stages also run through the AOT/PJRT backend for comparison.
//! This is the bench the EXPERIMENTS.md §Perf iteration log quotes.

use clo_hdnn::config::HdConfig;
use clo_hdnn::data::synthetic;
use clo_hdnn::hdc::packed;
use clo_hdnn::hdc::quantize::quantize_features;
use clo_hdnn::hdc::{ChvStore, HdBackend, HdClassifier, ProgressiveSearch, Trainer};
use clo_hdnn::runtime::NativeBackend;
use clo_hdnn::util::stats::{fmt_secs, Bench, Table};
use clo_hdnn::util::Rng;

fn main() {
    let cfg: HdConfig = synthetic::config("isolet").expect("builtin config");
    let cfg_name = "isolet";
    // one factor set (seed 1) shared by every measured pipeline
    let mut native = NativeBackend::seeded(cfg.clone(), 1, 8).expect("native backend");

    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..cfg.features()).map(|_| rng.normal_f32()).collect();
    let xq = quantize_features(&x, cfg.scale_x);
    let x8: Vec<f32> = (0..8).flat_map(|_| xq.clone()).collect();
    let mut store = ChvStore::new(cfg.clone());
    for c in 0..cfg.classes {
        let q: Vec<f32> = (0..cfg.dim()).map(|_| rng.range(-40, 41) as f32).collect();
        store.update(c, &q, 1.0).unwrap();
    }
    let qseg = native.encode_segment(&xq, 1, 0).unwrap();

    let bench = Bench::new(5, 40);
    println!("== L3 hot-path stages (config {cfg_name}: F={} D={} segs={}) ==",
             cfg.features(), cfg.dim(), cfg.segments);
    let mut t = Table::new(&["stage", "median", "p95", "notes"]);

    let s = bench.run(|| quantize_features(&x, cfg.scale_x));
    t.row(&["quantize features".into(), fmt_secs(s.median), fmt_secs(s.p95), "rust".into()]);

    let s = bench.run(|| native.encode_segment(&xq, 1, 0).unwrap());
    t.row(&["encode segment (native b1)".into(), fmt_secs(s.median), fmt_secs(s.p95), "kron".into()]);
    let s = bench.run(|| native.encode_segment(&x8, 8, 0).unwrap());
    t.row(&[
        "encode segment (native b8)".into(),
        fmt_secs(s.median),
        fmt_secs(s.p95),
        format!("{} per sample", fmt_secs(s.median / 8.0)),
    ]);

    let s = bench.run(|| native.encode_full(&xq, 1).unwrap());
    t.row(&["encode full (native b1)".into(), fmt_secs(s.median), fmt_secs(s.p95),
            format!("{} segs worth", cfg.segments)]);

    let s = bench.run(|| {
        native
            .search(&qseg, 1, store.segment(0), cfg.classes, cfg.seg_len())
            .unwrap()
    });
    t.row(&["partial search (native b1)".into(), fmt_secs(s.median), fmt_secs(s.p95),
            format!("{} CHVs", cfg.classes)]);
    let scalar_partial = s.median;

    // the XOR-tree path: same partial search over the bit-packed INT1 AM
    let qp = packed::pack_signs(&qseg);
    let s = bench.run(|| {
        native
            .search_packed(&qp, 1, store.packed().segment(0), cfg.classes, cfg.seg_len())
            .unwrap()
    });
    t.row(&[
        "partial search (packed b1)".into(),
        fmt_secs(s.median),
        fmt_secs(s.p95),
        format!("XOR+popcount, {:.1}x", scalar_partial / s.median),
    ]);

    // full-D associative search, scalar vs packed (the bench `clo_hdnn
    // bench` sweeps across configs)
    let qfull = native.encode_full(&xq, 1).unwrap();
    let mut chvs_full = Vec::with_capacity(cfg.classes * cfg.dim());
    for c in 0..cfg.classes {
        chvs_full.extend(store.class_hv(c));
    }
    let chvs_packed = packed::pack_rows(&chvs_full, cfg.classes, cfg.dim()).unwrap();
    let s = bench.run(|| {
        native
            .search(&qfull, 1, &chvs_full, cfg.classes, cfg.dim())
            .unwrap()
    });
    t.row(&["full search (scalar L1)".into(), fmt_secs(s.median), fmt_secs(s.p95),
            format!("{} x {} f32", cfg.classes, cfg.dim())]);
    let scalar_full = s.median;
    let qfp = packed::pack_signs(&qfull);
    let s = bench.run(|| {
        native
            .search_packed(&qfp, 1, &chvs_packed, cfg.classes, cfg.dim())
            .unwrap()
    });
    t.row(&[
        "full search (packed INT1)".into(),
        fmt_secs(s.median),
        fmt_secs(s.p95),
        format!("{} words, {:.1}x", packed::words_for(cfg.dim()), scalar_full / s.median),
    ]);
    t.print();

    // end-to-end progressive vs exhaustive classify on the native pipeline
    println!("\n== end-to-end progressive classify ==");
    let mut t2 = Table::new(&["pipeline", "median", "p95", "throughput"]);
    let mut cl = HdClassifier::new(
        Box::new(NativeBackend::seeded(cfg.clone(), 1, 8).unwrap()),
        ProgressiveSearch { tau: 0.5, min_segments: 1, ..Default::default() },
    );
    cl.store = store.clone();
    let s = bench.run(|| cl.classify(&x).unwrap());
    t2.row(&[
        "native progressive".into(),
        fmt_secs(s.median),
        fmt_secs(s.p95),
        format!("{:.0}/s", 1.0 / s.median),
    ]);
    let mut cl_full = HdClassifier::new(
        Box::new(NativeBackend::seeded(cfg.clone(), 1, 8).unwrap()),
        ProgressiveSearch { tau: f32::INFINITY, min_segments: usize::MAX, ..Default::default() },
    );
    cl_full.store = store.clone();
    let s = bench.run(|| cl_full.classify(&x).unwrap());
    t2.row(&[
        "native exhaustive".into(),
        fmt_secs(s.median),
        fmt_secs(s.p95),
        format!("{:.0}/s", 1.0 / s.median),
    ]);
    t2.print();

    // training path
    let train_bench = Bench::new(2, 10);
    let mut cl_train = HdClassifier::new(
        Box::new(NativeBackend::seeded(cfg.clone(), 1, 8).unwrap()),
        ProgressiveSearch { tau: 0.5, min_segments: 1, ..Default::default() },
    );
    let trainer = Trainer { retrain_epochs: 0 };
    let ds = clo_hdnn::data::Dataset::from_parts(
        (0..32).flat_map(|_| x.clone()).collect(),
        (0..32).map(|i| (i % cfg.classes) as u16).collect(),
        cfg.features(),
        cfg.classes,
    )
    .unwrap();
    let idx: Vec<usize> = (0..32).collect();
    let s = train_bench.run(|| trainer.train_indices(&mut cl_train, &ds, &idx).unwrap());
    println!(
        "\ntraining single-pass: {} per 32 samples ({} per update)",
        fmt_secs(s.median),
        fmt_secs(s.median / 32.0)
    );

    // PJRT comparison (only with --features pjrt and built artifacts)
    #[cfg(feature = "pjrt")]
    pjrt_comparison(&cfg, &xq, &x8, &store);
}

/// The AOT/PJRT twin of the stage table, when an engine can come up.
#[cfg(feature = "pjrt")]
fn pjrt_comparison(cfg: &HdConfig, xq: &[f32], x8: &[f32], store: &ChvStore) {
    use clo_hdnn::runtime::{Engine, Manifest, PjrtBackend};
    let Ok(mut engine) = Engine::load(Manifest::default_dir()) else {
        eprintln!("\n(pjrt comparison skipped: no artifacts; run `make artifacts`)");
        return;
    };
    let cfg_name = &cfg.name;
    let Ok(mut pjrt) = PjrtBackend::new(&mut engine, cfg_name, 1) else {
        eprintln!("\n(pjrt comparison skipped: no {cfg_name} executables in manifest)");
        return;
    };
    let bench = Bench::new(5, 40);
    let mut t = Table::new(&["stage", "median", "p95", "notes"]);
    println!("\n== PJRT comparison ==");
    let s = bench.run(|| pjrt.encode_segment(xq, 1, 0).unwrap());
    t.row(&["encode segment (PJRT b1)".into(), fmt_secs(s.median), fmt_secs(s.p95), "AOT Pallas".into()]);
    if let Ok(mut pjrt8) = PjrtBackend::new(&mut engine, cfg_name, 8) {
        let s = bench.run(|| pjrt8.encode_segment(x8, 8, 0).unwrap());
        t.row(&[
            "encode segment (PJRT b8)".into(),
            fmt_secs(s.median),
            fmt_secs(s.p95),
            format!("{} per sample", fmt_secs(s.median / 8.0)),
        ]);
    }
    let s = bench.run(|| pjrt.encode_full(xq, 1).unwrap());
    t.row(&["encode full (PJRT b1)".into(), fmt_secs(s.median), fmt_secs(s.p95),
            format!("{} segs worth", cfg.segments)]);
    let qseg = pjrt.encode_segment(xq, 1, 0).unwrap();
    let s = bench.run(|| {
        pjrt.search(&qseg, 1, store.segment(0), cfg.classes, cfg.seg_len())
            .unwrap()
    });
    t.row(&["partial search (PJRT b1)".into(), fmt_secs(s.median), fmt_secs(s.p95),
            format!("{} CHVs", cfg.classes)]);
    t.print();
}
