//! §Perf — L3 hot-path microbenchmarks: per-stage latency of the serving
//! loop (quantize / encode-segment / partial-search / full pipeline)
//! through both backends, plus the dynamic batcher's b8 amortization.
//! This is the bench the EXPERIMENTS.md §Perf iteration log quotes.

use clo_hdnn::config::HdConfig;
use clo_hdnn::data::TensorFile;
use clo_hdnn::hdc::encoder::SoftwareEncoder;
use clo_hdnn::hdc::quantize::quantize_features;
use clo_hdnn::hdc::{ChvStore, HdBackend, HdClassifier, ProgressiveSearch, Trainer};
use clo_hdnn::runtime::{Engine, Manifest, PjrtBackend};
use clo_hdnn::util::stats::{fmt_secs, Bench, Table};
use clo_hdnn::util::Rng;

fn main() {
    let Ok(mut engine) = Engine::load(Manifest::default_dir()) else {
        eprintln!("run `make artifacts` first");
        std::process::exit(1);
    };
    let cfg_name = "isolet";
    let cfg = engine.manifest.config(cfg_name).unwrap().clone();
    let tf = TensorFile::load(engine.manifest.dir.join(format!("hd_factors_{cfg_name}.bin")))
        .unwrap();
    let mut sw = SoftwareEncoder::new(
        cfg.clone(),
        tf.f32("a").unwrap().to_vec(),
        tf.f32("b").unwrap().to_vec(),
    )
    .unwrap();
    let mut pjrt = PjrtBackend::new(&mut engine, cfg_name, 1).unwrap();
    let mut pjrt8 = PjrtBackend::new(&mut engine, cfg_name, 8).unwrap();

    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..cfg.features()).map(|_| rng.normal_f32()).collect();
    let xq = quantize_features(&x, cfg.scale_x);
    let x8: Vec<f32> = (0..8).flat_map(|_| xq.clone()).collect();
    let mut store = ChvStore::new(cfg.clone());
    for c in 0..cfg.classes {
        let q: Vec<f32> = (0..cfg.dim()).map(|_| rng.range(-40, 41) as f32).collect();
        store.update(c, &q, 1.0).unwrap();
    }
    let qseg = sw.encode_segment(&xq, 1, 0).unwrap();

    let bench = Bench::new(5, 40);
    println!("== L3 hot-path stages (config {cfg_name}: F={} D={} segs={}) ==",
             cfg.features(), cfg.dim(), cfg.segments);
    let mut t = Table::new(&["stage", "median", "p95", "notes"]);

    let s = bench.run(|| quantize_features(&x, cfg.scale_x));
    t.row(&["quantize features".into(), fmt_secs(s.median), fmt_secs(s.p95), "rust".into()]);

    let s = bench.run(|| sw.encode_segment(&xq, 1, 0).unwrap());
    t.row(&["encode segment (software)".into(), fmt_secs(s.median), fmt_secs(s.p95), "rust twin".into()]);
    let s = bench.run(|| pjrt.encode_segment(&xq, 1, 0).unwrap());
    t.row(&["encode segment (PJRT b1)".into(), fmt_secs(s.median), fmt_secs(s.p95), "AOT Pallas".into()]);
    let s = bench.run(|| pjrt8.encode_segment(&x8, 8, 0).unwrap());
    t.row(&[
        "encode segment (PJRT b8)".into(),
        fmt_secs(s.median),
        fmt_secs(s.p95),
        format!("{} per sample", fmt_secs(s.median / 8.0)),
    ]);

    let s = bench.run(|| pjrt.encode_full(&xq, 1).unwrap());
    t.row(&["encode full (PJRT b1)".into(), fmt_secs(s.median), fmt_secs(s.p95), "16 segs worth".into()]);

    let s = bench.run(|| {
        pjrt.search(&qseg, 1, store.segment(0), cfg.classes, cfg.seg_len())
            .unwrap()
    });
    t.row(&["partial search (PJRT b1)".into(), fmt_secs(s.median), fmt_secs(s.p95), "26 CHVs".into()]);
    let s = bench.run(|| {
        clo_hdnn::hdc::distance::l1_batch(&qseg, 1, store.segment(0), cfg.classes, cfg.seg_len())
            .unwrap()
    });
    t.row(&["partial search (software)".into(), fmt_secs(s.median), fmt_secs(s.p95), "rust twin".into()]);
    t.print();

    // end-to-end progressive classify, both backends
    println!("\n== end-to-end progressive classify ==");
    let mut t2 = Table::new(&["pipeline", "median", "p95", "throughput"]);
    for (name, backend) in [
        ("PJRT", Box::new(PjrtBackend::new(&mut engine, cfg_name, 1).unwrap()) as Box<dyn HdBackend>),
        ("software", Box::new(sw.clone()) as Box<dyn HdBackend>),
    ] {
        let mut cl = HdClassifier::new(backend, ProgressiveSearch { tau: 0.5, min_segments: 1 });
        cl.store = store.clone();
        let s = bench.run(|| cl.classify(&x).unwrap());
        t2.row(&[
            format!("{name} progressive"),
            fmt_secs(s.median),
            fmt_secs(s.p95),
            format!("{:.0}/s", 1.0 / s.median),
        ]);
        let mut cl_full =
            HdClassifier::new(match name {
                "PJRT" => Box::new(PjrtBackend::new(&mut engine, cfg_name, 1).unwrap()) as Box<dyn HdBackend>,
                _ => Box::new(sw.clone()) as Box<dyn HdBackend>,
            }, ProgressiveSearch { tau: f32::INFINITY, min_segments: usize::MAX });
        cl_full.store = store.clone();
        let s = bench.run(|| cl_full.classify(&x).unwrap());
        t2.row(&[
            format!("{name} exhaustive"),
            fmt_secs(s.median),
            fmt_secs(s.p95),
            format!("{:.0}/s", 1.0 / s.median),
        ]);
    }
    t2.print();

    // training path
    let train_bench = Bench::new(2, 10);
    let mut cl = HdClassifier::new(
        Box::new(PjrtBackend::new(&mut engine, cfg_name, 1).unwrap()),
        ProgressiveSearch { tau: 0.5, min_segments: 1 },
    );
    let trainer = Trainer { retrain_epochs: 0 };
    let ds = clo_hdnn::data::Dataset::from_parts(
        (0..32).flat_map(|_| x.clone()).collect(),
        (0..32).map(|i| (i % cfg.classes) as u16).collect(),
        cfg.features(),
        cfg.classes,
    )
    .unwrap();
    let idx: Vec<usize> = (0..32).collect();
    let s = train_bench.run(|| trainer.train_indices(&mut cl, &ds, &idx).unwrap());
    println!(
        "\ntraining single-pass: {} per 32 samples ({} per update)",
        fmt_secs(s.median),
        fmt_secs(s.median / 32.0)
    );
}
