//! Ablations over the chip's configuration envelope (Fig.11 summary rows):
//! * QHV precision INT1-8 (the chip's inference precision modes),
//! * HDC dimension D = 1024-8192,
//! * retrain-epoch count (gradient-free training depth).
//!
//! These back the design choices DESIGN.md calls out: D=2048 with INT8 QHVs
//! is the accuracy knee; INT1 (Hamming/XOR-tree mode) trades ~2-4 points of
//! accuracy for 8x narrower datapaths; retraining converges in 1-2 epochs.

use clo_hdnn::config::HdConfig;
use clo_hdnn::data::Dataset;
use clo_hdnn::hdc::encoder::SoftwareEncoder;
use clo_hdnn::hdc::{HdBackend, HdClassifier, ProgressiveSearch, Trainer};
use clo_hdnn::util::stats::Table;
use clo_hdnn::util::Rng;

fn blobs(classes: usize, per: usize, feat: usize, noise: f32, seed: u64) -> Dataset {
    let mut prng = Rng::new(0xAB1A);
    let protos: Vec<Vec<f32>> = (0..classes)
        .map(|_| (0..feat).map(|_| prng.normal_f32() * 40.0).collect())
        .collect();
    let mut rng = Rng::new(seed);
    let mut x = Vec::new();
    let mut y = Vec::new();
    for c in 0..classes {
        for _ in 0..per {
            x.extend(protos[c].iter().map(|&v| v + rng.normal_f32() * noise));
            y.push(c as u16);
        }
    }
    Dataset::from_parts(x, y, feat, classes).unwrap()
}

fn run(cfg: &HdConfig, train: &Dataset, test: &Dataset, retrain: usize) -> (f64, f64) {
    let mut enc = SoftwareEncoder::random(cfg.clone(), 5);
    let n = train.n.min(64);
    let sample: Vec<f32> = (0..n)
        .flat_map(|i| {
            clo_hdnn::hdc::quantize::quantize_features(train.sample(i), cfg.scale_x)
        })
        .collect();
    enc.calibrate(&sample, n);
    let mut cl = HdClassifier::new(
        Box::new(enc),
        ProgressiveSearch { tau: 0.5, min_segments: 1, ..Default::default() },
    );
    let trainer = Trainer { retrain_epochs: retrain };
    trainer.train_all(&mut cl, train).unwrap();
    let r = cl
        .evaluate((0..test.n).map(|i| (test.sample(i).to_vec(), test.label(i))))
        .unwrap();
    (r.accuracy, r.complexity_reduction())
}

fn main() {
    let train = blobs(26, 60, 640, 95.0, 1);
    let test = blobs(26, 20, 640, 95.0, 2);

    println!("== ablation: QHV precision INT1-8 (D=2048) ==");
    let mut t = Table::new(&["qbits", "accuracy", "complexity saved", "QHV bits/inference"]);
    for qbits in [1u8, 2, 4, 8] {
        let mut cfg = HdConfig::synthetic("ab", 32, 20, 64, 32, 16, 26);
        cfg.qbits = qbits;
        let (acc, saved) = run(&cfg, &train, &test, 1);
        t.row(&[
            format!("INT{qbits}"),
            format!("{acc:.4}"),
            format!("{:.1}%", saved * 100.0),
            format!("{}", cfg.dim() * qbits as usize),
        ]);
    }
    t.print();

    println!("\n== ablation: HDC dimension D (INT8) ==");
    let mut t2 = Table::new(&["D", "accuracy", "complexity saved", "CHV cache (KiB)"]);
    for d1 in [32usize, 64, 128, 256] {
        let cfg = HdConfig::synthetic("ab", 32, 20, d1, 32, 16, 26);
        let (acc, saved) = run(&cfg, &train, &test, 1);
        t2.row(&[
            format!("{}", cfg.dim()),
            format!("{acc:.4}"),
            format!("{:.1}%", saved * 100.0),
            format!("{}", 26 * cfg.dim() / 1024),
        ]);
    }
    t2.print();

    println!("\n== ablation: retrain epochs (gradient-free training depth) ==");
    let mut t3 = Table::new(&["retrain epochs", "accuracy"]);
    for ep in [0usize, 1, 2, 4] {
        let cfg = HdConfig::synthetic("ab", 32, 20, 64, 32, 16, 26);
        let (acc, _) = run(&cfg, &train, &test, ep);
        t3.row(&[format!("{ep}"), format!("{acc:.4}")]);
    }
    t3.print();
    println!("\n(chip envelope: D 1024-8192, INT1-8 inference — Fig.11 summary rows)");
}
