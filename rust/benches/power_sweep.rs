//! Fig.10 — (a) WCFE energy efficiency & peak throughput, (b) HDC energy
//! efficiency & peak throughput across the 0.7-1.2 V / 50-250 MHz DVFS
//! envelope; (c) latency and (d) energy breakdowns of CIFAR-100 normal-mode
//! inference. Calibration endpoints are the paper's measured numbers;
//! everything else is derived by the chip model.

use clo_hdnn::config::{ChipConfig, HdConfig};
use clo_hdnn::data::TensorFile;
use clo_hdnn::energy::{Domain, EnergyModel};
use clo_hdnn::runtime::Manifest;
use clo_hdnn::sim::{Chip, Mode};
use clo_hdnn::util::stats::Table;
use clo_hdnn::util::Rng;
use clo_hdnn::wcfe::codebook::LayerCodebook;
use clo_hdnn::wcfe::conv::ConvLayer;
use clo_hdnn::wcfe::{Codebook, WcfeModel};

fn wcfe_fixture() -> (WcfeModel, Codebook) {
    if let Ok(m) = Manifest::load(Manifest::default_dir()) {
        if let Some(w) = m.wcfe.clone() {
            if let (Ok(tf), Ok(cb_tf)) = (
                TensorFile::load(m.dir.join(&w.weights)),
                TensorFile::load(m.dir.join(&w.codebook)),
            ) {
                let model = WcfeModel::load(&tf, &w.channels, w.fc_out, w.image_hw, w.image_c)
                    .unwrap();
                let cb = Codebook::load(
                    &cb_tf,
                    &["conv1", "conv2", "conv3"],
                    (w.channels.last().unwrap() * w.fc_out) as u64,
                )
                .unwrap();
                return (model, cb);
            }
        }
    }
    // random twin fallback
    let mut rng = Rng::new(1);
    let chans = [(3usize, 32usize), (32, 64), (64, 128)];
    let mut convs = Vec::new();
    let mut layers = Vec::new();
    for (i, &(ci, co)) in chans.iter().enumerate() {
        let w: Vec<f32> = (0..9 * ci * co).map(|_| rng.normal_f32() * 0.1).collect();
        layers.push(LayerCodebook::from_weights(&format!("conv{}", i + 1), &w, 9 * ci, co, 16));
        convs.push(ConvLayer { w, c_in: ci, c_out: co });
    }
    (
        WcfeModel { convs, fc: vec![0.0; 128 * 512], fc_out: 512, image_hw: 32, image_c: 3 },
        Codebook { layers, dense_tail_bits: 128 * 512 * 16 },
    )
}

fn main() {
    let chip = Chip::default();
    let energy = EnergyModel::default();
    let cfgs = ChipConfig::default();

    println!("== Fig.10a/b: DVFS sweep — energy efficiency & peak throughput ==");
    let mut table = Table::new(&[
        "V", "f (MHz)", "WCFE TFLOPS/W", "HDC TOPS/W", "WCFE peak GFLOPS", "HDC peak GOPS",
    ]);
    for op in cfgs.dvfs_sweep(6) {
        // WCFE peak: 64 MACs/cycle = 128 FLOPs/cycle; HDC: 256 adds + 8
        // search ops per cycle
        table.row(&[
            format!("{:.1}", op.voltage),
            format!("{:.0}", op.freq_mhz),
            format!("{:.2}", energy.efficiency(Domain::Wcfe, op.voltage)),
            format!("{:.2}", energy.efficiency(Domain::Hdc, op.voltage)),
            format!("{:.1}", energy.peak_throughput_gops(128.0, op)),
            format!("{:.1}", energy.peak_throughput_gops(264.0, op)),
        ]);
    }
    table.print();
    println!(
        "paper Fig.10: WCFE 1.44-4.66 TFLOPS/W, HDC 1.29-3.78 TOPS/W over 0.7-1.2 V"
    );

    // Fig.10c/d — CIFAR-100 normal-mode breakdown
    let hd = HdConfig::synthetic("cifar100", 32, 16, 128, 32, 16, 100);
    let (model, cb) = wcfe_fixture();
    println!("\n== Fig.10c/d: CIFAR-100 normal-mode inference breakdown @0.9V ==");
    let r = chip.simulate_inference(&hd, Mode::Normal, hd.segments, Some((&model, &cb)), 0.9);
    let mut t2 = Table::new(&["module", "cycles", "cycle %", "energy (uJ)", "energy %"]);
    let (tot_c, tot_e) = (r.trace.total_cycles(None), r.trace.total_energy_j(None));
    for m in &r.trace.modules {
        t2.row(&[
            m.name.clone(),
            format!("{}", m.cycles),
            format!("{:.1}%", 100.0 * m.cycles as f64 / tot_c as f64),
            format!("{:.3}", m.energy_j * 1e6),
            format!("{:.1}%", 100.0 * m.energy_j / tot_e),
        ]);
    }
    t2.print();
    println!(
        "WCFE share: {:.1}% latency, {:.1}% energy (paper Fig.10c/d: 87.7% / 94.2%)",
        r.wcfe_latency_share * 100.0,
        r.wcfe_energy_share * 100.0
    );

    // bypassing benefit (the dual-mode motivation)
    let bypass = chip.simulate_inference(&hd, Mode::Bypass, hd.segments, None, 0.9);
    println!(
        "\nWCFE bypassing (dual mode): {:.2} uJ -> {:.3} uJ per inference ({:.0}x) — \
         why simple datasets skip the FE",
        r.energy_j * 1e6,
        bypass.energy_j * 1e6,
        r.energy_j / bypass.energy_j
    );

    // progressive search scales the HDC slice further (ties Fig.4 to Fig.10)
    println!("\n== energy vs segments-used (bypass mode, 0.9V) ==");
    let mut t3 = Table::new(&["segments used", "latency (us)", "energy (uJ)"]);
    for segs in [16usize, 12, 8, 6, 4] {
        let r = chip.simulate_inference(&hd, Mode::Bypass, segs, None, 0.9);
        t3.row(&[
            format!("{segs}/16"),
            format!("{:.2}", r.latency_s * 1e6),
            format!("{:.4}", r.energy_j * 1e6),
        ]);
    }
    t3.print();
}
