//! Fig.9 — end-to-end continual-learning accuracy: (a) ISOLET and
//! (b) UCIHAR in bypass mode, (c) CIFAR-100 (WCFE features) in normal
//! mode, Clo-HDnn's gradient-free HDC vs the FP baseline (replay-SGD
//! standing in for [5]) and naive SGD.
//!
//! Needs `make artifacts`. Accuracy per task checkpoint == the Fig.9 bars.

use clo_hdnn::baselines::LinearSgd;
use clo_hdnn::cl::learners::{ContinualLearner, HdLearner, SgdLearner};
use clo_hdnn::cl::ClHarness;
use clo_hdnn::data::{Dataset, TaskStream};
use clo_hdnn::hdc::encoder::SoftwareEncoder;
use clo_hdnn::hdc::{HdClassifier, ProgressiveSearch, Trainer};
use clo_hdnn::data::TensorFile;
use clo_hdnn::runtime::Manifest;
use clo_hdnn::util::stats::Table;

fn hd_learner(m: &Manifest, cfg_name: &str, tau: f32) -> HdLearner {
    // software backend (bit-identical to the AOT kernels, golden-pinned) —
    // keeps the full Fig.9 sweep fast; examples/cl_isolet.rs runs the same
    // flow through PJRT.
    let cfg = m.config(cfg_name).unwrap().clone();
    let tf = TensorFile::load(m.dir.join(format!("hd_factors_{cfg_name}.bin"))).unwrap();
    let enc = SoftwareEncoder::new(
        cfg.clone(),
        tf.f32("a").unwrap().to_vec(),
        tf.f32("b").unwrap().to_vec(),
    )
    .unwrap();
    HdLearner::new(
        HdClassifier::new(
            Box::new(enc),
            ProgressiveSearch { tau, min_segments: 1, ..Default::default() },
        ),
        Trainer { retrain_epochs: 2 },
    )
}

fn main() {
    let Ok(m) = Manifest::load(Manifest::default_dir()) else {
        eprintln!("run `make artifacts` first");
        std::process::exit(1);
    };

    // (panel, config, tasks, mode note)
    let panels = [
        ("Fig.9a", "isolet", 5, "bypass"),
        ("Fig.9b", "ucihar", 3, "bypass"),
        ("Fig.9c", "cifar100", 10, "normal (WCFE features)"),
    ];
    for (panel, cfg_name, n_tasks, mode) in panels {
        let cfg = m.config(cfg_name).unwrap().clone();
        let train = Dataset::load(m.dataset_path(&format!("ds_{cfg_name}_train")).unwrap()).unwrap();
        let test = Dataset::load(m.dataset_path(&format!("ds_{cfg_name}_test")).unwrap()).unwrap();
        let stream = TaskStream::class_incremental(&train, n_tasks, 1);
        let mut h = ClHarness::new(&train, &test, &stream);
        h.eval_cap = 120;

        println!(
            "\n== {panel}: {cfg_name} ({mode}), {} classes over {n_tasks} tasks ==",
            cfg.classes
        );
        let mut learners: Vec<Box<dyn ContinualLearner>> = vec![
            Box::new(hd_learner(&m, cfg_name, 0.5)),
            Box::new(SgdLearner(LinearSgd::new(train.dim, cfg.classes, 0.05, 4, 1000, 7))),
            Box::new(SgdLearner(LinearSgd::new(train.dim, cfg.classes, 0.05, 4, 0, 7))),
        ];
        let mut table = Table::new(&[
            "learner", "acc after each task", "final", "forgetting", "segments",
        ]);
        for l in &mut learners {
            let run = h.run(l.as_mut()).unwrap();
            table.row(&[
                run.learner.clone(),
                run.matrix
                    .curve()
                    .iter()
                    .map(|a| format!("{a:.2}"))
                    .collect::<Vec<_>>()
                    .join(" "),
                format!("{:.4}", run.final_accuracy),
                format!("{:.4}", run.mean_forgetting),
                run.mean_segments
                    .map(|s| format!("{s:.1}/{}", cfg.segments))
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
        table.print();
    }
    println!(
        "\npaper Fig.9: Clo-HDnn tracks the FP baseline [5] with negligible drop on \
         all three benchmarks while learning gradient-free; naive SGD forgets."
    );
}
