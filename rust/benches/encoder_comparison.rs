//! Fig.5 — Kronecker HD encoder vs RP [11], cRP [4], ID-LEVEL [12].
//!
//! Regenerates the paper's encoder-comparison panel: arithmetic ops,
//! encoder parameter storage, measured software encode latency, and the
//! headline ratios (paper: 43x speedup, 1376x memory saving at the large
//! operating point). Absolute times are this machine's; the *ratios* are
//! the reproduction target.

use clo_hdnn::baselines::encoders::{BaselineEncoder, CrpEncoder, IdLevelEncoder, RpEncoder};
use clo_hdnn::config::HdConfig;
use clo_hdnn::hdc::encoder::{kron_cost, SoftwareEncoder};
use clo_hdnn::hdc::HdBackend;
use clo_hdnn::util::prop::gen;
use clo_hdnn::util::stats::{fmt_secs, Bench, Table};
use clo_hdnn::util::Rng;

fn human_bits(bits: u64) -> String {
    if bits >= 8 * 1024 * 1024 {
        format!("{:.1} MiB", bits as f64 / 8.0 / 1024.0 / 1024.0)
    } else if bits >= 8 * 1024 {
        format!("{:.1} KiB", bits as f64 / 8.0 / 1024.0)
    } else {
        format!("{bits} b")
    }
}

fn main() {
    // the paper's worst-case point: F=640 (ISOLET padded), D=8192
    let points = [
        ("D=2048", HdConfig::synthetic("f5a", 32, 20, 64, 32, 16, 26)),
        ("D=4096", HdConfig::synthetic("f5b", 32, 20, 128, 32, 16, 26)),
        ("D=8192", HdConfig::synthetic("f5c", 32, 20, 256, 32, 16, 26)),
    ];
    let bench = Bench::new(2, 8);

    for (label, cfg) in &points {
        println!("\n== Fig.5 encoder comparison @ F={} {} ==", cfg.features(), label);
        let mut rng = Rng::new(1);
        let x = gen::int8_vec(&mut rng, cfg.features());

        let mut kron = SoftwareEncoder::random(cfg.clone(), 2);
        let kcost = kron_cost(cfg);
        let kt = bench.run(|| kron.encode_full(&x, 1).unwrap());

        let mut table = Table::new(&[
            "encoder", "ops/encode", "memory", "time/encode", "speedup", "mem saving",
        ]);
        table.row(&[
            "Kronecker (ours)".into(),
            format!("{}", kcost.ops),
            human_bits(kcost.mem_bits),
            fmt_secs(kt.median),
            "1.00x".into(),
            "1.00x".into(),
        ]);

        let baselines: Vec<Box<dyn BaselineEncoder>> = vec![
            Box::new(RpEncoder::new(cfg.clone(), 3)),
            Box::new(CrpEncoder::new(cfg.clone(), 4)),
            Box::new(IdLevelEncoder::new(cfg.clone(), 32, 5)),
        ];
        for enc in &baselines {
            let t = bench.run(|| enc.encode(&x));
            table.row(&[
                enc.name().into(),
                format!("{}", enc.ops()),
                human_bits(enc.mem_bits()),
                fmt_secs(t.median),
                format!("{:.1}x", t.median / kt.median),
                format!("{:.0}x", enc.mem_bits() as f64 / kcost.mem_bits as f64),
            ]);
        }
        table.print();
        let rp = &baselines[0];
        println!(
            "model-level: op ratio {:.1}x, memory ratio {:.0}x (paper Fig.5: 43x speedup, 1376x memory @D=8192)",
            rp.ops() as f64 / kcost.ops as f64,
            rp.mem_bits() as f64 / kcost.mem_bits as f64
        );
    }

    // accuracy is not sacrificed: all encoders classify the same blobs
    println!("\n== encoder quality check (nearest-CHV accuracy on synthetic blobs) ==");
    let cfg = HdConfig::synthetic("f5q", 8, 8, 32, 32, 8, 10);
    let mut rng = Rng::new(9);
    let protos: Vec<Vec<f32>> = (0..10)
        .map(|_| (0..64).map(|_| rng.normal_f32() * 40.0).collect())
        .collect();
    let sample = |rng: &mut Rng, c: usize| -> Vec<f32> {
        protos[c].iter().map(|&v| v + rng.normal_f32() * 14.0).collect()
    };
    let mut encoders: Vec<(String, Box<dyn FnMut(&[f32]) -> Vec<f32>>)> = {
        let mut kron = SoftwareEncoder::random(cfg.clone(), 10);
        let rp = RpEncoder::new(cfg.clone(), 11);
        let crp = CrpEncoder::new(cfg.clone(), 12);
        let id = IdLevelEncoder::new(cfg.clone(), 16, 13);
        vec![
            ("Kronecker".into(), Box::new(move |x: &[f32]| kron.encode_full(x, 1).unwrap())
                as Box<dyn FnMut(&[f32]) -> Vec<f32>>),
            ("RP".into(), Box::new(move |x: &[f32]| rp.encode(x))),
            ("cRP".into(), Box::new(move |x: &[f32]| crp.encode(x))),
            ("ID-LEVEL".into(), Box::new(move |x: &[f32]| id.encode(x))),
        ]
    };
    let mut table = Table::new(&["encoder", "accuracy (20 samples/class)"]);
    for (name, encode) in encoders.iter_mut().map(|(n, e)| (n.clone(), e)) {
        // bundle 10 train samples per class, test on 20
        let mut chvs = vec![0.0f32; 10 * cfg.dim()];
        let mut r2 = Rng::new(77);
        for c in 0..10 {
            for _ in 0..10 {
                let q = encode(&sample(&mut r2, c));
                for (i, v) in q.iter().enumerate() {
                    chvs[c * cfg.dim() + i] = (chvs[c * cfg.dim() + i] + v).clamp(-127.0, 127.0);
                }
            }
        }
        let mut correct = 0;
        let total = 200;
        for t in 0..total {
            let c = t % 10;
            let q = encode(&sample(&mut r2, c));
            let d = clo_hdnn::hdc::distance::l1_batch(&q, 1, &chvs, 10, cfg.dim()).unwrap();
            let best = d
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            correct += usize::from(best == c);
        }
        table.row(&[name, format!("{:.3}", correct as f64 / total as f64)]);
    }
    table.print();
}
