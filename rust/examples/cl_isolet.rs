//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): continual learning on the
//! ISOLET-like workload in bypass mode, through the full stack — dataset ->
//! task-incremental stream -> Kronecker encoder (NativeBackend) ->
//! progressive search -> gradient-free updates — against the FP32 SGD
//! baseline (with and without replay) and nearest-class-mean.
//!
//! Hermetic by default (synthetic config + deterministic blob data):
//!
//!     cargo run --release --example cl_isolet
//!
//! With AOT artifacts present (`--artifacts <dir>` or ./artifacts), the
//! manifest config, datasets, and production Kronecker factors are used.
//!
//! Flags: --config isolet|ucihar|tiny  --tasks N  --tau F  --eval-cap N

use clo_hdnn::baselines::{LinearSgd, NearestMean};
use clo_hdnn::cl::learners::{HdLearner, NcmLearner, SgdLearner};
use clo_hdnn::cl::ClHarness;
use clo_hdnn::data::{synthetic, Dataset, TaskStream};
use clo_hdnn::hdc::quantize::quantize_features;
use clo_hdnn::hdc::{HdClassifier, ProgressiveSearch, Trainer};
use clo_hdnn::runtime::{Manifest, NativeBackend};
use clo_hdnn::sim::{Chip, Mode};
use clo_hdnn::util::stats::Table;
use clo_hdnn::util::Args;

fn main() -> clo_hdnn::Result<()> {
    let args = Args::from_env();
    let cfg_name = args.str_or("config", "isolet");
    let n_tasks = args.usize_or("tasks", 5)?;
    let tau = args.f64_or("tau", 0.5)? as f32;

    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Manifest::default_dir);

    // artifacts when present, hermetic synthetic workload otherwise
    let (cfg, train, test, backend) = if dir.join("manifest.json").exists() {
        let m = Manifest::load(&dir)?;
        let cfg = m.config(&cfg_name)?.clone();
        let train = Dataset::load(m.dataset_path(&format!("ds_{cfg_name}_train"))?)?;
        let test = Dataset::load(m.dataset_path(&format!("ds_{cfg_name}_test"))?)?;
        let backend = NativeBackend::from_manifest(&m, &cfg_name, 8)?;
        (cfg, train, test, backend)
    } else {
        let cfg = synthetic::config(&cfg_name)?;
        let (train, test) = synthetic::blobs(&cfg, 30, 12, 17);
        let mut backend = NativeBackend::seeded(cfg.clone(), 7, 8)?;
        let calib_n = train.n.min(16);
        let mut calib = Vec::with_capacity(calib_n * cfg.features());
        for i in 0..calib_n {
            calib.extend(quantize_features(train.sample(i), cfg.scale_x));
        }
        backend.calibrate(&calib, calib_n);
        (cfg, train, test, backend)
    };
    println!(
        "== continual learning on {cfg_name}: {} train / {} test samples, \
         {} classes in {n_tasks} tasks, F={} D={} ==",
        train.n, test.n, cfg.classes, cfg.features(), cfg.dim()
    );

    let stream = TaskStream::class_incremental(&train, n_tasks, 1);
    let mut harness = ClHarness::new(&train, &test, &stream);
    harness.eval_cap = args.usize_or("eval-cap", 150)?;

    // learners
    let mut hd = HdLearner::new(
        HdClassifier::new(
            Box::new(backend),
            ProgressiveSearch { tau, min_segments: 1, ..Default::default() },
        ),
        Trainer { retrain_epochs: 1 },
    );
    let mut sgd = SgdLearner(LinearSgd::new(train.dim, cfg.classes, 0.05, 4, 0, 7));
    let mut sgd_replay = SgdLearner(LinearSgd::new(train.dim, cfg.classes, 0.05, 4, 500, 7));
    let mut ncm = NcmLearner(NearestMean::new(train.dim, cfg.classes));

    let t0 = std::time::Instant::now();
    let hd_run = harness.run(&mut hd)?;
    let hd_wall = t0.elapsed().as_secs_f64();
    let sgd_run = harness.run(&mut sgd)?;
    let sgd_replay_run = harness.run(&mut sgd_replay)?;
    let ncm_run = harness.run(&mut ncm)?;

    let mut t = Table::new(&[
        "learner", "final acc", "forgetting", "acc curve", "mean segs",
    ]);
    for run in [&hd_run, &sgd_run, &sgd_replay_run, &ncm_run] {
        t.row(&[
            run.learner.clone(),
            format!("{:.4}", run.final_accuracy),
            format!("{:.4}", run.mean_forgetting),
            run.matrix
                .curve()
                .iter()
                .map(|a| format!("{a:.2}"))
                .collect::<Vec<_>>()
                .join(" "),
            run.mean_segments
                .map(|s| format!("{s:.2}/{}", cfg.segments))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    t.print();

    // throughput + chip-model summary for the HDC path
    let trained_inferences = (0..n_tasks).map(|t| (t + 1) * harness.eval_cap).sum::<usize>();
    println!(
        "\nHDC stack wall time {:.2}s (~{:.0} train+infer ops/s on the NativeBackend)",
        hd_wall,
        (train.n + trained_inferences) as f64 / hd_wall
    );
    if let Some(segs) = hd_run.mean_segments {
        let chip = Chip::default();
        let r = chip.simulate_inference(&cfg, Mode::Bypass, segs.round() as usize, None, 0.7);
        println!(
            "chip model @0.7V: {:.2} us / inference, {:.3} uJ (progressive, {:.1}% work skipped)",
            r.latency_s * 1e6,
            r.energy_j * 1e6,
            (1.0 - segs / cfg.segments as f64) * 100.0
        );
    }
    Ok(())
}
