//! Quickstart — fully hermetic: build the pure-Rust NativeBackend on a
//! built-in synthetic config, train the HDC classifier with gradient-free
//! bundling, classify with progressive search, and print the chip model's
//! latency/energy estimate for what just ran. No Python artifacts, no PJRT:
//!
//!     cargo run --release --example quickstart
//!
//! (For the AOT/PJRT path, see `run_hlo` / `serve_cifar` with
//! `--features pjrt` and a populated artifacts/ directory.)

use clo_hdnn::data::synthetic;
use clo_hdnn::hdc::quantize::quantize_features;
use clo_hdnn::hdc::{HdClassifier, ProgressiveSearch, Trainer};
use clo_hdnn::runtime::NativeBackend;
use clo_hdnn::sim::{Chip, Mode};
use clo_hdnn::util::stats::fmt_secs;

fn main() -> clo_hdnn::Result<()> {
    // 1. a built-in synthetic operating point + deterministic blob datasets
    let cfg = synthetic::config("tiny")?;
    let (train, test) = synthetic::blobs(&cfg, 40, 10, 17);
    println!(
        "config tiny: F={} D={} classes={} segments={} | {} train / {} test samples",
        cfg.features(),
        cfg.dim(),
        cfg.classes,
        cfg.segments,
        train.n,
        test.n
    );

    // 2. the NativeBackend (pure Rust; same HdBackend trait the PJRT
    //    backend implements), calibrated on a few training samples
    let mut backend = NativeBackend::seeded(cfg.clone(), 7, 8)?;
    let calib_n = train.n.min(16);
    let mut calib = Vec::with_capacity(calib_n * cfg.features());
    for i in 0..calib_n {
        calib.extend(quantize_features(train.sample(i), cfg.scale_x));
    }
    backend.calibrate(&calib, calib_n);
    let mut classifier = HdClassifier::new(
        Box::new(backend),
        ProgressiveSearch { tau: 0.5, min_segments: 1, ..Default::default() },
    );

    // 3. gradient-free training: single pass + one mistake-driven epoch
    let idx: Vec<usize> = (0..train.n).collect();
    let report = Trainer { retrain_epochs: 1 }.train_indices(&mut classifier, &train, &idx)?;
    println!("trained on {} samples; retrain mistakes per epoch: {:?}",
             report.samples, report.mistakes);

    // 4. progressive inference
    let eval = classifier.evaluate(
        (0..test.n).map(|i| (test.sample(i).to_vec(), test.label(i))))?;
    println!(
        "accuracy {:.4} | {:.2}/{} segments used on average -> {:.1}% of the \
         encode+search work skipped (Fig.4)",
        eval.accuracy,
        eval.mean_segments,
        eval.total_segments,
        eval.complexity_reduction() * 100.0
    );

    // 5. what would this cost on the 40nm chip?
    let chip = Chip::default();
    for v in [0.7, 1.2] {
        let r = chip.simulate_inference(&cfg, Mode::Bypass,
                                        eval.mean_segments.round() as usize, None, v);
        println!(
            "chip model @ {:.1}V/{:.0}MHz: {} per inference, {:.3} uJ",
            r.op.voltage, r.op.freq_mhz, fmt_secs(r.latency_s), r.energy_j * 1e6
        );
    }
    Ok(())
}
