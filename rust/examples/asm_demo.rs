//! ISA programming-model demo (Fig.8): build the progressive-inference and
//! training programs via intrinsics, show assembly + 20-bit bytecode, then
//! EXECUTE them on the functional chip device — real Kronecker encoding and
//! search driven entirely by the instruction sequencer.
//!
//!     cargo run --release --example asm_demo

use clo_hdnn::config::HdConfig;
use clo_hdnn::hdc::encoder::SoftwareEncoder;
use clo_hdnn::isa::intrinsics::{program_inference, program_train};
use clo_hdnn::isa::Interpreter;
use clo_hdnn::sim::{Chip, SimDevice};
use clo_hdnn::util::Rng;

fn main() -> clo_hdnn::Result<()> {
    let cfg = HdConfig::synthetic("demo", 8, 8, 32, 32, 8, 4);
    println!(
        "== Clo-HDnn ISA demo: F={} D={} {} segments, {} classes ==\n",
        cfg.features(), cfg.dim(), cfg.segments, cfg.classes
    );

    // the intrinsics emit the exact 20-bit bytecode the chip sequencer runs
    let train_prog = program_train(&cfg, 2);
    println!("clo_train_single_pass(class=2) -> {} instructions:", train_prog.len());
    println!("{}", train_prog.disassemble());

    let infer_prog = program_inference(&cfg, 0, false, 0.3, 1);
    println!(
        "clo_infer_progressive(tau=0.3) -> {} instructions (first 12 shown):",
        infer_prog.len()
    );
    for line in infer_prog.disassemble().lines().take(12) {
        println!("{line}");
    }
    println!("  ...\nbytecode words: {:?} ...\n",
             &infer_prog.bytecode()[..6.min(infer_prog.len())]);

    // run them on the functional device
    let mut dev = SimDevice::new(
        Box::new(SoftwareEncoder::random(cfg.clone(), 42)),
        Chip::default(),
    );
    let mut rng = Rng::new(1);
    let protos: Vec<Vec<f32>> = (0..cfg.classes)
        .map(|_| (0..cfg.features()).map(|_| rng.normal_f32() * 40.0).collect())
        .collect();

    let itp = Interpreter::default();
    for (c, p) in protos.iter().enumerate() {
        dev.queue_input(p.clone());
        let r = itp.run(&program_train(&cfg, c), &mut dev)?;
        println!("trained class {c}: {} instructions, {} datapath cycles", r.instructions, r.cycles);
    }

    println!();
    let mut cycles_progressive = 0u64;
    for (c, p) in protos.iter().enumerate() {
        let noisy: Vec<f32> = p.iter().map(|&v| v + rng.normal_f32() * 5.0).collect();
        dev.queue_input(noisy);
        let r = itp.run(&infer_prog, &mut dev)?;
        cycles_progressive += r.cycles;
        println!(
            "classified -> {:?} (true {c}), exit_flag={}, {} cycles",
            dev.predicted, r.state.exit_flag, r.cycles
        );
        assert_eq!(dev.predicted, Some(c));
    }

    // compare against the non-progressive program
    let full_prog = program_inference(&cfg, 0, false, f32::INFINITY, 1);
    let mut cycles_full = 0u64;
    for p in &protos {
        dev.queue_input(p.clone());
        cycles_full += itp.run(&full_prog, &mut dev)?.cycles;
    }
    println!(
        "\nprogressive vs exhaustive datapath cycles: {} vs {} ({:.1}% saved) — Fig.4 in ISA form",
        cycles_progressive,
        cycles_full,
        100.0 * (1.0 - cycles_progressive as f64 / cycles_full as f64)
    );
    Ok(())
}
