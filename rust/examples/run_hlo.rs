//! Debug utility: load an HLO text file, execute it on the PJRT CPU client
//! with deterministic inputs, print output stats.
//!
//! Usage: cargo run --example run_hlo -- <file.hlo.txt> <shape1> [shape2...]
//! Shapes as comma-separated dims, e.g. 1,64. `i` prefix = i32 scalar.

use anyhow::Result;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let path = &args[0];
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file(path)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp)?;
    let mut literals = Vec::new();
    for spec in &args[1..] {
        if let Some(v) = spec.strip_prefix('i') {
            literals.push(xla::Literal::scalar(v.parse::<i32>()?));
        } else {
            let dims: Vec<i64> = spec.split(',').map(|d| d.parse().unwrap()).collect();
            let n: i64 = dims.iter().product();
            let data: Vec<f32> = (0..n).map(|i| ((i % 13) as f32) - 6.0).collect();
            literals.push(xla::Literal::vec1(&data).reshape(&dims)?);
        }
    }
    let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
    let out = result.to_tuple1()?;
    let v = out.to_vec::<f32>()?;
    let nonzero = v.iter().filter(|x| **x != 0.0).count();
    println!(
        "out len={} nonzero={} head={:?}",
        v.len(),
        nonzero,
        &v[..v.len().min(8)]
    );
    Ok(())
}
