//! Serving driver: normal-mode (WCFE -> HDC) classification of CIFAR-100-
//! like images through the coordinator — dual-mode routing, the AOT WCFE
//! artifact, progressive search — under Poisson traffic, reporting
//! latency percentiles and throughput.
//!
//!     make artifacts && cargo run --release --example serve_cifar
//!
//! Flags: --samples N  --rate RPS  --tau F  --learn N

use clo_hdnn::coordinator::{
    BackendSpec, Coordinator, CoordinatorOptions, Payload, ServeMetrics,
};
use clo_hdnn::data::Dataset;
use clo_hdnn::runtime::Manifest;
use clo_hdnn::util::stats::fmt_secs;
use clo_hdnn::util::{Args, Rng};

fn main() -> clo_hdnn::Result<()> {
    let args = Args::from_env();
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Manifest::default_dir);
    let manifest = Manifest::load(&dir)?;
    let cfg = manifest.config("cifar100")?.clone();

    // feature-space sets for online learning; image set for serving
    let feat_train = Dataset::load(manifest.dataset_path("ds_cifar100_train")?)?;
    let img_test = Dataset::load(manifest.dataset_path("ds_cifar100_img_test")?)?;

    let coord = Coordinator::start(CoordinatorOptions {
        backend: BackendSpec::Pjrt { artifacts: dir, config: "cifar100".into() },
        model: String::new(),
        tau: args.f64_or("tau", 0.5)? as f32,
        min_segments: args.usize_or("min-seg", 1)?,
        search_mode: Default::default(),
        mode_policy: Default::default(),
        wcfe: Default::default(),
        queue_depth: 256,
        threads: args.usize_or("threads", 0)?,
        snapshot_path: None,
        snapshot_every: 0,
        restore_path: None,
        wal_path: None,
        wal_fsync_every: 1,
    })?;

    // online gradient-free learning on WCFE features
    let learn_n = args.usize_or("learn", 2000)?.min(feat_train.n);
    let t0 = std::time::Instant::now();
    for i in 0..learn_n {
        coord.call(Payload::Learn(feat_train.sample(i).to_vec(), feat_train.label(i)))?;
    }
    println!(
        "learned {learn_n} samples in {} ({:.0} updates/s)",
        fmt_secs(t0.elapsed().as_secs_f64()),
        learn_n as f64 / t0.elapsed().as_secs_f64()
    );

    // serve raw images (normal mode: WCFE artifact runs per request)
    let n = args.usize_or("samples", 300)?.min(img_test.n);
    let rate = args.f64_or("rate", 300.0)?;
    let mut rng = Rng::new(11);
    let mut metrics = ServeMetrics::default();
    let mut correct = 0usize;
    let t1 = std::time::Instant::now();
    for i in 0..n {
        std::thread::sleep(std::time::Duration::from_secs_f64(rng.exponential(rate)));
        let r = coord.call(Payload::Image(img_test.sample(i).to_vec()))?;
        match r.error {
            Some(e) => {
                eprintln!("request {i} failed: {e}");
                metrics.record_error();
            }
            None => {
                metrics.record(r.latency_s, r.segments_used, r.early_exit, r.used_wcfe);
                correct += usize::from(r.class == Some(img_test.label(i)));
            }
        }
    }
    metrics.wall_s = t1.elapsed().as_secs_f64();

    println!(
        "served {} image requests (normal mode, WCFE ran on {}):",
        metrics.total, metrics.wcfe_runs
    );
    println!(
        "  accuracy {:.4} | p50 {} p95 {} mean {} | {:.1} req/s",
        correct as f64 / n as f64,
        fmt_secs(metrics.latency_percentile(50.0)),
        fmt_secs(metrics.latency_percentile(95.0)),
        fmt_secs(metrics.mean_latency()),
        metrics.throughput_rps()
    );
    println!(
        "  progressive search: {:.2}/{} segments on average (-{:.1}% complexity), \
         {:.1}% early exits",
        metrics.mean_segments(),
        cfg.segments,
        metrics.complexity_reduction(cfg.segments) * 100.0,
        100.0 * metrics.early_exits as f64 / metrics.total.max(1) as f64
    );
    Ok(())
}
