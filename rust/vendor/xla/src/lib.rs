//! Offline stub of the `xla` PJRT bindings.
//!
//! The `pjrt` cargo feature of `clo_hdnn` compiles against this crate so the
//! feature-gated code keeps type-checking in environments where the real XLA
//! toolchain (PJRT C API + bindings) is not vendored. Every entry point that
//! would touch PJRT returns [`Error`] at runtime with a clear message.
//!
//! To run the real thing, point the workspace `xla` dependency at a checkout
//! of the actual bindings (same API: `PjRtClient`, `PjRtLoadedExecutable`,
//! `Literal`, `HloModuleProto`, `XlaComputation`).

use std::fmt;

/// Error raised by every stubbed PJRT entry point.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT unavailable — this build links the offline xla stub; \
         vendor the real xla bindings to use the pjrt feature at runtime"
    )))
}

/// Element types the engine lowers with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    I32,
}

/// Host-side tensor value.
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        unavailable("Literal::create_from_shape_and_untyped_data")
    }

    pub fn scalar(_v: i32) -> Literal {
        Literal
    }

    pub fn vec1(_v: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module (text format).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// A computation handed to the compiler.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client (CPU platform in this repo).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("PJRT unavailable"), "{err}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::scalar(1).to_tuple1().is_err());
        assert!(Literal::vec1(&[1.0]).reshape(&[1]).is_err());
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0; 4])
            .is_err());
    }
}
