//! Offline drop-in subset of the `anyhow` crate.
//!
//! The workspace builds with no network access, so instead of pulling
//! `anyhow` from crates.io this path dependency implements exactly the
//! surface the codebase uses:
//!
//! * [`Error`] — an error value carrying a context chain (outermost first);
//! * [`Result<T>`] — `Result<T, Error>` with a defaulted error parameter;
//! * [`anyhow!`] / [`bail!`] — format-style construction / early return;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on any `Result`
//!   whose error converts into [`Error`];
//! * `From<E>` for every `E: std::error::Error + Send + Sync + 'static`,
//!   so `?` lifts std errors (io, utf8, parse, channel recv, ...);
//! * [`Error::downcast_ref`] — recover the typed root error (e.g. a
//!   serving client telling a `ServerError` apart from transport failure).
//!
//! Display semantics match anyhow: `{}` prints the outermost message,
//! `{:#}` prints the whole chain joined by `": "`, and `{:?}` prints the
//! message plus a `Caused by:` list.

use std::fmt;

/// `Result<T, Error>` with the error type defaulted, like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error value: a chain of human-readable messages, outermost
/// context first, root cause last. When the value was lifted from a typed
/// `std::error::Error` (via `?` or `.into()`), that root error is kept and
/// recoverable through [`Error::downcast_ref`] — attaching context never
/// erases it.
pub struct Error {
    msgs: Vec<String>,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from a single printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msgs: vec![message.to_string()], source: None }
    }

    /// Prepend a layer of context (the new outermost message).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.msgs.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.msgs.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.msgs.last().expect("error has at least one message")
    }

    /// The typed root error, when this value was lifted from one and the
    /// type matches — `None` for message-only errors ([`anyhow!`]/
    /// [`bail!`]). Context layers are transparent, like real anyhow.
    pub fn downcast_ref<E>(&self) -> Option<&E>
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        self.source.as_deref().and_then(|s| s.downcast_ref::<E>())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.msgs.join(": "))
        } else {
            f.write_str(&self.msgs[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msgs[0])?;
        if self.msgs.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for m in &self.msgs[1..] {
                write!(f, "\n    {m}")?;
            }
        }
        Ok(())
    }
}

// The same blanket conversion real anyhow ships: any std error (and its
// source chain) lifts into `Error` via `?`. Coherence works because `Error`
// itself intentionally does NOT implement `std::error::Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        Error { msgs, source: Some(Box::new(e)) }
    }
}

/// Attach context to the error branch of a `Result`, like `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error with an eagerly evaluated context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

// One impl covers both `Result<T, Error>` (via the reflexive `From`) and
// `Result<T, E>` for std errors (via the blanket `From` above).
impl<T, E> Context<T> for Result<T, E>
where
    Error: From<E>,
{
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

/// Construct an [`Error`] from format arguments, like `anyhow::anyhow!`.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_missing() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn display_plain_and_alternate() {
        let e = io_missing().unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        let full = format!("{e:#}");
        assert!(full.starts_with("reading config: "), "{full}");
    }

    #[test]
    fn macro_formats_and_bails() {
        fn f(n: usize) -> Result<()> {
            if n > 3 {
                bail!("n too big: {n}");
            }
            Err(anyhow!("fixed {}", "msg"))
        }
        assert_eq!(format!("{}", f(9).unwrap_err()), "n too big: 9");
        assert_eq!(format!("{}", f(0).unwrap_err()), "fixed msg");
    }

    #[test]
    fn context_on_anyhow_result_chains() {
        let e: Error = anyhow!("root");
        let r: Result<()> = Err(e);
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: root");
        assert_eq!(e.root_cause(), "root");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn question_mark_lifts_std_errors() {
        fn f() -> Result<i32> {
            let v: i32 = "not a number".parse()?;
            Ok(v)
        }
        assert!(f().is_err());
    }

    #[test]
    fn debug_lists_causes() {
        let e = io_missing().unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn downcast_ref_recovers_the_typed_root_through_context() {
        let e = io_missing().unwrap_err();
        let io = e.downcast_ref::<std::io::Error>().expect("typed root survives context");
        assert_eq!(io.kind(), std::io::ErrorKind::NotFound);
        // wrong type: no match
        assert!(e.downcast_ref::<std::num::ParseIntError>().is_none());
        // message-only errors carry no typed root
        let e: Error = anyhow!("just a message");
        assert!(e.downcast_ref::<std::io::Error>().is_none());
        let e = e.context("outer");
        assert!(e.downcast_ref::<std::io::Error>().is_none());
    }
}
