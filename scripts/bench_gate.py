#!/usr/bin/env python3
"""Gate bench JSON documents against a committed ratio baseline.

The old CI gate asserted raw "fast path beats scalar" inequalities
(packed >= scalar, sign-GEMM >= scalar) directly against one noisy run,
which flaked whenever a shared runner's scheduler jitter landed on the
nanosecond-scale single-row timings. This gate compares *regression
deltas* instead: every tracked speedup ratio must stay at or above
gate_fraction x its committed baseline (bench/BASELINE.json). The ratios
are dimensionless -- fast path vs scalar measured in the SAME process on
the SAME machine -- so a slow runner shifts both numerators and
denominators together and the gate only trips on genuine kernel
regressions.

Exit status 0 iff every check passes; the full per-metric comparison is
written to --out (BENCH_delta.json) for artifact upload either way.
Stdlib only; no third-party dependencies.
"""

import argparse
import json
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--classifier", required=True, help="BENCH_classifier.json path")
    ap.add_argument("--encoder", required=True, help="BENCH_encoder.json path")
    ap.add_argument("--baseline", required=True, help="bench/BASELINE.json path")
    ap.add_argument(
        "--dualmode",
        default=None,
        help="BENCH_dualmode.json path (structural dual-mode invariants; no baseline)",
    )
    ap.add_argument("--out", default="BENCH_delta.json", help="delta report output path")
    args = ap.parse_args()

    cls_doc = load(args.classifier)
    enc_doc = load(args.encoder)
    base = load(args.baseline)
    frac = float(base.get("gate_fraction", 0.9))

    checks = []

    def check(metric, measured, baseline):
        floor = baseline * frac
        checks.append(
            {
                "metric": metric,
                "measured": measured,
                "baseline": baseline,
                "floor": floor,
                "ratio_to_baseline": (measured / baseline) if baseline else None,
                "pass": measured >= floor,
            }
        )

    # Structural sanity first (cheap, catches format drift), then the
    # delta checks for every ratio the baseline tracks.
    for cfg_name, b in base.get("classifier", {}).items():
        cfg = cls_doc["configs"][cfg_name]
        for row in cfg["progressive"]:
            assert 0.0 <= row["complexity_saving"] <= 1.0, row
        check(f"classifier.{cfg_name}.search.speedup", cfg["search"]["speedup"], b["search_speedup"])

    for cfg_name, b in base.get("encoder", {}).items():
        cfg = enc_doc["configs"][cfg_name]
        assert cfg["rows"], f"encoder bench emitted no rows for {cfg_name}"
        for row in cfg["rows"]:
            assert row["signgemm_ns_per_encode"] > 0.0, row
            assert row["signgemm_samples_per_s"] > 0.0, row
        by_rows = {int(r["rows"]): r for r in cfg["rows"]}
        for rows_key, spec in b["rows"].items():
            row = by_rows.get(int(rows_key))
            assert row is not None, f"baseline tracks rows={rows_key} but the bench skipped it"
            check(
                f"encoder.{cfg_name}.rows{rows_key}.signgemm_speedup",
                row["signgemm_speedup"],
                spec["signgemm_speedup"],
            )

    # Dual-mode report: rate-independent invariants only. Escalation *rates*
    # depend on the margin and the noise draw, so gating an easy/hard
    # ordering would flake; the accounting identities below hold for every
    # margin by construction.
    if args.dualmode:
        dm = load(args.dualmode)
        cells = dm.get("scenarios", {})
        assert cells, f"{args.dualmode} carries no scenario cells"
        for name, c in cells.items():
            assert c["errors"] == 0, (name, c["errors"])
            assert 0.0 <= c["bypass_fraction"] <= 1.0, (name, c["bypass_fraction"])
            assert c["bypass"] + c["normal"] == c["infers"], (name, c)
            assert c["escalations"] <= c["normal"], (name, c)
            if c["infers"] > 0:
                assert c["energy_per_query_j"] > 0.0, (name, c["energy_per_query_j"])
            ops = c["fe_ops"]
            assert 0 < ops["clustered_per_query"] < ops["dense_per_query"], (name, ops)
        print(
            "dualmode ok: %d cells (%s), policy=%s"
            % (len(cells), ",".join(sorted(cells)), dm.get("policy", "?"))
        )

    assert checks, "baseline tracks no metrics; nothing was gated"
    delta = {
        "version": 1,
        "gate_fraction": frac,
        "kernel": cls_doc.get("kernel", "unknown"),
        "checks": checks,
        "pass": all(c["pass"] for c in checks),
    }
    with open(args.out, "w") as f:
        json.dump(delta, f, indent=2)
        f.write("\n")

    for c in checks:
        tag = "ok  " if c["pass"] else "FAIL"
        print(
            "%s %s: measured %.3f vs baseline %.3f (floor %.3f)"
            % (tag, c["metric"], c["measured"], c["baseline"], c["floor"])
        )
    if not delta["pass"]:
        print(f"bench gate FAILED; full comparison in {args.out}", file=sys.stderr)
        return 1
    print("bench gate ok: %d metrics, kernel=%s" % (len(checks), delta["kernel"]))
    return 0


if __name__ == "__main__":
    sys.exit(main())
